//! E9 — Figure 7 (complete system): accuracy and throughput of the
//! Taylor/ILM divider vs the Newton, Goldschmidt and digit-recurrence
//! baselines, plus the (order × ILM-budget) design-space sweep and the
//! cycle-model latency comparison.

use tsdiv::analysis::{measure_accuracy_f32, Workload};
use tsdiv::divider::{
    goldschmidt::GoldschmidtDivider, longdiv::LongDivider, newton::NewtonDivider, BackendKind,
    Divider, TaylorDivider,
};
use tsdiv::fp::{F32, Rounding};
use tsdiv::harness::{gen_batch, gen_repeated_divisor_batch, timed_section};
use tsdiv::hw::{divider_timing, longdiv_timing};
use tsdiv::taylor::TaylorConfig;
use tsdiv::util::json::Json;
use tsdiv::util::table::{sig, Align, Table};

/// Parse the bench's own CLI (args after `--` in
/// `cargo bench --bench divider_throughput -- ...`): `--tile` takes a
/// comma-separated list of kernel tile widths for the sweep that pins
/// `DEFAULT_TILE` (ROADMAP), defaulting to the full `4,8,16,32` grid so
/// the CI datapoint always records the per-tile keys.
fn tile_sweep_widths() -> Vec<usize> {
    let cmd = tsdiv::util::cli::Command::new(
        "divider_throughput",
        "E9 divider throughput bench (tile sweep options)",
    )
    .opt(
        "tile",
        "4,8,16,32",
        "comma-separated kernel tile widths to sweep (e.g. --tile 8)",
    )
    // Cargo appends `--bench` to every benchmark binary's argv when
    // invoked via `cargo bench`, harness = false included — accept it
    // as a no-op so the CI invocation keeps working.
    .flag("bench", "accepted for cargo-bench compatibility (no-op)");
    let parsed = match cmd.parse(std::env::args().skip(1)) {
        Ok(p) => p,
        Err(help) => {
            eprintln!("{help}");
            std::process::exit(2);
        }
    };
    let spec = parsed.get_or("tile", "4,8,16,32").to_string();
    let mut tiles: Vec<usize> = Vec::new();
    for part in spec.split(',') {
        // Every entry must parse: a typo must not silently shrink the
        // sweep (a missing width would read as a warming-up gate metric
        // instead of the benchmark the user asked for).
        match part.trim().parse::<usize>() {
            Ok(t) if (1..=1usize << 20).contains(&t) => tiles.push(t),
            _ => {
                eprintln!("option --tile: '{part}' is not a valid width (want e.g. 4,8,16,32)");
                std::process::exit(2);
            }
        }
    }
    if tiles.is_empty() {
        eprintln!("option --tile: '{spec}' has no widths (want e.g. 4,8,16,32)");
        std::process::exit(2);
    }
    tiles
}

fn main() {
    let tiles = tile_sweep_widths();
    println!("\n===== E9: Fig 7 — complete divider vs baselines =====\n");

    // Accuracy across workloads (vs exactly-rounded digit recurrence).
    let mut t = Table::new(
        "accuracy vs gold (5 000 samples per cell)",
        &["divider", "workload", "max ulp", "mean ulp", "exact %"],
    )
    .aligns(&[Align::Left, Align::Left, Align::Right, Align::Right, Align::Right]);
    let mk: Vec<Box<dyn Fn() -> Box<dyn Divider>>> = vec![
        Box::new(|| Box::new(TaylorDivider::paper_exact())),
        Box::new(|| Box::new(TaylorDivider::paper_ilm(8))),
        Box::new(|| Box::new(TaylorDivider::paper_ilm(2))),
        Box::new(|| Box::new(NewtonDivider::paper_default())),
        Box::new(|| Box::new(GoldschmidtDivider::paper_default())),
    ];
    for make in &mk {
        for wl in [Workload::LogUniform, Workload::SignificandOnly, Workload::RandomBits] {
            let mut d = make();
            let r = measure_accuracy_f32(d.as_mut(), wl, 5_000, 17);
            t.row(&[
                r.divider.clone(),
                wl.name().to_string(),
                r.max_ulp.to_string(),
                format!("{:.4}", r.mean_ulp),
                format!("{:.2}", r.exact_rate * 100.0),
            ]);
        }
    }
    t.print();

    // Design-space sweep: Taylor order × ILM budget → worst-case ulp.
    let mut t = Table::new(
        "max ulp by (Taylor order × ILM corrections), significand workload",
        &["order", "ilm=1", "ilm=2", "ilm=4", "ilm=8", "exact"],
    )
    .aligns(&[Align::Right; 6]);
    for order in [2u32, 3, 5] {
        let mut row = vec![order.to_string()];
        for budget in [Some(1u32), Some(2), Some(4), Some(8), None] {
            let cfg = TaylorConfig {
                order,
                ..TaylorConfig::paper_default(60)
            };
            let kind = match budget {
                Some(iterations) => BackendKind::Ilm { iterations },
                None => BackendKind::Exact,
            };
            let mut d = TaylorDivider::new(cfg, kind);
            let r = measure_accuracy_f32(&mut d, Workload::SignificandOnly, 2_000, 3);
            row.push(r.max_ulp.to_string());
        }
        t.row(&row);
    }
    t.print();

    // Software-model throughput (the L3 hot path the perf pass optimizes).
    println!();
    let batch = gen_batch(Workload::LogUniform, 4096, 9);
    let mut results = Vec::new();
    for (label, mut d) in [
        ("taylor exact", Box::new(TaylorDivider::paper_exact()) as Box<dyn Divider>),
        ("taylor ilm8", Box::new(TaylorDivider::paper_ilm(8))),
        ("newton", Box::new(NewtonDivider::paper_default())),
        ("goldschmidt", Box::new(GoldschmidtDivider::paper_default())),
        ("longdiv (gold)", Box::new(LongDivider::new())),
    ] {
        let m = timed_section(&format!("{label}: 4096 divisions"), || {
            let mut acc = 0u32;
            for i in 0..batch.len() {
                acc ^= d.div_f32(batch.a[i], batch.b[i]).to_bits();
            }
            tsdiv::util::black_box(acc);
        });
        results.push((label, m.items_per_sec(4096)));
    }
    let mut t = Table::new("word-level model throughput", &["divider", "Mdiv/s"])
        .aligns(&[Align::Left, Align::Right]);
    for (label, thr) in &results {
        t.row(&[label.to_string(), format!("{:.2}", thr / 1e6)]);
    }
    t.print();

    // Scalar vs batch datapath on identical operands: the batch path
    // hoists per-op setup, monomorphizes the backend once per batch and
    // caches repeated divisor reciprocals (bit-identical by property
    // test; re-asserted below).
    println!();
    let (a_bits, b_bits) = batch.bits_f32();
    let lanes = a_bits.len() as u64;
    let mut out = vec![0u64; a_bits.len()];
    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    let mut runs: Vec<(&str, Vec<u64>, Vec<u64>, Box<dyn Fn() -> TaylorDivider>)> = vec![
        (
            "taylor exact",
            a_bits.clone(),
            b_bits.clone(),
            Box::new(TaylorDivider::paper_exact),
        ),
        (
            "taylor ilm8",
            a_bits.clone(),
            b_bits.clone(),
            Box::new(|| TaylorDivider::paper_ilm(8)),
        ),
    ];
    let rep = gen_repeated_divisor_batch(4096, 16, 5);
    let (rep_a, rep_b) = rep.bits_f32();
    runs.push((
        "taylor exact, repeated divisors (16 distinct)",
        rep_a,
        rep_b,
        Box::new(TaylorDivider::paper_exact),
    ));
    // Interleaved (not contiguous) repeats: only the widened N-way
    // reciprocal cache can hit here — a one-entry cache thrashes.
    let few = gen_repeated_divisor_batch(4096, 6, 7);
    let (few_a0, few_b0) = few.bits_f32();
    let stride = 4096 / 6;
    let interleave = |v: &[u64]| -> Vec<u64> {
        (0..v.len()).map(|i| v[(i * stride + i / 6) % v.len()]).collect()
    };
    runs.push((
        "taylor exact, interleaved divisors (6 distinct)",
        interleave(&few_a0),
        interleave(&few_b0),
        Box::new(TaylorDivider::paper_exact),
    ));
    for (label, aa, bb, make) in &runs {
        let mut d = make();
        let m_scalar = timed_section(&format!("{label}: scalar div_bits × {lanes}"), || {
            let mut acc = 0u64;
            for i in 0..aa.len() {
                acc ^= d.div_bits(aa[i], bb[i], F32, Rounding::NearestEven);
            }
            tsdiv::util::black_box(acc);
        });
        let m_batch = timed_section(&format!("{label}: div_bits_batch × {lanes}"), || {
            d.div_bits_batch(aa, bb, F32, Rounding::NearestEven, &mut out);
            tsdiv::util::black_box(out[0]);
        });
        // Bit-identity guard: `out` still holds the timed batch results
        // for these operands; they must agree with the scalar path on
        // every lane of the benchmarked workload.
        for i in 0..aa.len() {
            let want = d.div_bits(aa[i], bb[i], F32, Rounding::NearestEven);
            assert_eq!(out[i], want, "{label}: batch != scalar at lane {i}");
        }
        rows.push((
            label.to_string(),
            m_scalar.items_per_sec(lanes),
            m_batch.items_per_sec(lanes),
        ));
    }
    let mut t = Table::new(
        "scalar vs batch datapath (4096 lanes)",
        &["divider", "scalar Mdiv/s", "batch Mdiv/s", "speedup"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right]);
    for (label, s, bthr) in &rows {
        t.row(&[
            label.clone(),
            format!("{:.2}", s / 1e6),
            format!("{:.2}", bthr / 1e6),
            format!("{:.2}x", bthr / s),
        ]);
    }
    t.print();

    // The same datapath across every format the service offers — the
    // format-parametric claim behind the typed DivRequest API: one
    // staged kernel serves f16/bf16/f32/f64. Per format, three worker
    // datapaths: the NativeScalar baseline (per-lane div_bits loop), the
    // kernel on the pinned scalar lane engine ("autovec" — the stage
    // loops as the compiler vectorizes them), and the kernel on the
    // auto-resolved engine (explicit SIMD where the host has a vector
    // engine — AVX-512, AVX2 or NEON, widest detected) —
    // the Simd-vs-Autovec-vs-NativeScalar comparison the lane engine is
    // about. All three are asserted bit-identical on the benchmarked
    // operands.
    println!();
    use tsdiv::coordinator::{Backend, KernelBackend, ScalarNativeBackend};
    use tsdiv::simd::{simd_available, SimdChoice};
    // Force the vector engine when the host has one — a silent scalar
    // fallback must never masquerade as a SIMD measurement; hosts
    // without a vector engine measure (and label) the scalar engine
    // instead, and the simd-vs-autovec ratio is only recorded when SIMD
    // really ran. The resolved engine name rides in the datapoint as
    // `simd_engine`, so the history records which ISA each CI box
    // actually measured.
    let simd_on = simd_available();
    let simd_choice = if simd_on {
        SimdChoice::Forced
    } else {
        SimdChoice::Scalar
    };
    let simd_engine = simd_choice.resolve_lenient();
    let mut t = Table::new(
        &format!(
            "worker datapath by format (4096 lanes, taylor exact; simd engine = {})",
            simd_engine.name()
        ),
        &[
            "format",
            "scalar Mdiv/s",
            "autovec Mdiv/s",
            "simd Mdiv/s",
            "simd/scalar",
            "simd/autovec",
        ],
    )
    .aligns(&[
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    // simd column: None on hosts without a vector engine — there the
    // "simd" backend would be the autovec backend again, so re-timing
    // it would only produce scalar-vs-scalar noise under a SIMD label.
    let mut fmt_rows: Vec<(String, f64, f64, Option<f64>)> = Vec::new();
    for fmt in tsdiv::fp::ALL_FORMATS {
        let (fa, fb) = tsdiv::harness::gen_bits_batch(fmt, 4096, 8, 21);
        let mut scalar = ScalarNativeBackend::new(5, None).expect("scalar backend");
        let mut autovec = KernelBackend::new(
            5,
            tsdiv::kernel::KernelConfig {
                simd: SimdChoice::Scalar,
                ..tsdiv::kernel::KernelConfig::default()
            },
        )
        .expect("autovec kernel backend");
        let m_scalar = timed_section(&format!("{}: NativeScalar × 4096", fmt.name()), || {
            let q = scalar
                .divide(&fa, &fb, fmt, Rounding::NearestEven)
                .expect("scalar backend");
            tsdiv::util::black_box(q[0]);
        });
        let m_autovec = timed_section(&format!("{}: Kernel/autovec × 4096", fmt.name()), || {
            let q = autovec
                .divide(&fa, &fb, fmt, Rounding::NearestEven)
                .expect("autovec kernel backend");
            tsdiv::util::black_box(q[0]);
        });
        // Bit-identity guard on the benchmarked operands.
        let qs = scalar.divide(&fa, &fb, fmt, Rounding::NearestEven).unwrap();
        let qa = autovec.divide(&fa, &fb, fmt, Rounding::NearestEven).unwrap();
        assert_eq!(qa, qs, "{}: autovec kernel != scalar on bench workload", fmt.name());
        let simd_rate = if simd_on {
            let mut kern = KernelBackend::new(
                5,
                tsdiv::kernel::KernelConfig {
                    simd: simd_choice,
                    ..tsdiv::kernel::KernelConfig::default()
                },
            )
            .expect("kernel backend");
            let m_kernel = timed_section(&format!("{}: Kernel/simd × 4096", fmt.name()), || {
                let q = kern
                    .divide(&fa, &fb, fmt, Rounding::NearestEven)
                    .expect("kernel backend");
                tsdiv::util::black_box(q[0]);
            });
            let qk = kern.divide(&fa, &fb, fmt, Rounding::NearestEven).unwrap();
            assert_eq!(qk, qs, "{}: simd kernel != scalar on bench workload", fmt.name());
            Some(m_kernel.items_per_sec(4096))
        } else {
            None
        };
        fmt_rows.push((
            fmt.name().to_string(),
            m_scalar.items_per_sec(4096),
            m_autovec.items_per_sec(4096),
            simd_rate,
        ));
    }
    for (name, s, av, k) in &fmt_rows {
        let (ksimd, kps, kpav) = match k {
            Some(k) => (
                format!("{:.2}", k / 1e6),
                format!("{:.2}x", k / s),
                format!("{:.2}x", k / av),
            ),
            None => ("n/a".into(), "n/a".into(), "n/a".into()),
        };
        t.row(&[
            name.clone(),
            format!("{:.2}", s / 1e6),
            format!("{:.2}", av / 1e6),
            ksimd,
            kps,
            kpav,
        ]);
    }
    t.print();

    // Kernel tile-width sweep (ROADMAP: pin DEFAULT_TILE from data):
    // the same f32 workload through the kernel backend at each width,
    // on the same pinned engine as the rows above, with bit-identity
    // asserted across widths. Each width lands in the JSON datapoint as
    // `kernel_tile{N}_div_per_s_f32`, so the accumulated BENCH_HISTORY
    // gives the CI-box numbers the default is chosen from.
    println!();
    let mut t = Table::new(
        &format!(
            "kernel tile sweep (f32, 4096 lanes, engine = {}; default tile = {})",
            simd_engine.name(),
            tsdiv::kernel::DEFAULT_TILE
        ),
        &["tile", "Mdiv/s", "vs default"],
    )
    .aligns(&[Align::Right, Align::Right, Align::Right]);
    let (ta, tb) = tsdiv::harness::gen_bits_batch(F32, 4096, 8, 33);
    let mut tile_rows: Vec<(usize, f64)> = Vec::new();
    let mut tile_ref: Option<Vec<u64>> = None;
    for &tile in &tiles {
        let mut kern = KernelBackend::new(
            5,
            tsdiv::kernel::KernelConfig {
                tile,
                simd: simd_choice,
                ..tsdiv::kernel::KernelConfig::default()
            },
        )
        .expect("tile-sweep kernel backend");
        let m = timed_section(&format!("tile {tile}: Kernel × 4096"), || {
            let q = kern
                .divide(&ta, &tb, F32, Rounding::NearestEven)
                .expect("tile-sweep kernel backend");
            tsdiv::util::black_box(q[0]);
        });
        // Tile width must never change a bit.
        let q = kern.divide(&ta, &tb, F32, Rounding::NearestEven).unwrap();
        let reference = tile_ref.get_or_insert_with(|| q.clone());
        assert_eq!(&q, reference, "tile={tile}: results differ across tile widths");
        tile_rows.push((tile, m.items_per_sec(4096)));
    }
    let default_rate = tile_rows
        .iter()
        .find(|(t, _)| *t == tsdiv::kernel::DEFAULT_TILE)
        .map(|&(_, r)| r);
    for &(tile, rate) in &tile_rows {
        let rel = match default_rate {
            Some(d) if d > 0.0 => format!("{:.2}x", rate / d),
            _ => "n/a".into(),
        };
        t.row(&[tile.to_string(), format!("{:.2}", rate / 1e6), rel]);
    }
    t.print();

    // ILM priority-encoder pass, per detected engine: one
    // `priority_encode_batch` call over a 4096-lane operand array per
    // timed iteration — the pass the ILM correction recursion runs once
    // per stage, vectorized via `vplzcntq` on AVX-512 and the `vclzq`
    // half-select on NEON (AVX2 shares the scalar chain). Zero lanes
    // are salted in like settled ILM lanes. Each engine's rate lands in
    // the datapoint as `pe_batch_per_s_{engine}` — per_s keys, so the
    // direction-aware trend gate guards every engine this box detects —
    // and every engine is asserted bit-identical to scalar on the
    // benchmarked operands.
    println!();
    let mut t = Table::new(
        "ILM priority-encoder pass (4096 lanes) by engine",
        &["engine", "Mlanes/s", "vs scalar"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right]);
    let pe_ops: Vec<u64> = {
        let mut rng = tsdiv::util::rng::Rng::new(77);
        (0..4096usize)
            .map(|i| {
                if i % 7 == 0 {
                    0
                } else {
                    rng.next_u64() >> (rng.below(8) * 8)
                }
            })
            .collect()
    };
    let mut k_ref = vec![0u32; pe_ops.len()];
    let mut r_ref = vec![0u64; pe_ops.len()];
    tsdiv::simd::Engine::Scalar.priority_encode_batch(&pe_ops, &mut k_ref, &mut r_ref);
    let mut pe_rows: Vec<(&'static str, f64)> = Vec::new();
    for eng in tsdiv::simd::engines_available() {
        let mut k = vec![0u32; pe_ops.len()];
        let mut r = vec![0u64; pe_ops.len()];
        let m = timed_section(&format!("pe batch [{}] × 4096", eng.name()), || {
            eng.priority_encode_batch(&pe_ops, &mut k, &mut r);
            tsdiv::util::black_box(r[0]);
        });
        assert_eq!(k, k_ref, "{}: pe k differs from scalar", eng.name());
        assert_eq!(r, r_ref, "{}: pe r differs from scalar", eng.name());
        pe_rows.push((eng.name(), m.items_per_sec(4096)));
    }
    let scalar_pe_rate = pe_rows[0].1;
    for &(name, rate) in &pe_rows {
        let rel = if scalar_pe_rate > 0.0 {
            format!("{:.2}x", rate / scalar_pe_rate)
        } else {
            "n/a".into()
        };
        t.row(&[name.to_string(), format!("{:.2}", rate / 1e6), rel]);
    }
    t.print();

    // Record the comparison for the bench trajectory.
    let mut j = Json::obj();
    j.set("bench", "divider_throughput".into());
    j.set("lanes", lanes.into());
    j.set("simd_engine", simd_engine.name().into());
    for &(tile, rate) in &tile_rows {
        j.set(&format!("kernel_tile{tile}_div_per_s_f32"), rate.into());
    }
    for &(name, rate) in &pe_rows {
        j.set(&format!("pe_batch_per_s_{name}"), rate.into());
    }
    for (name, s, av, k) in &fmt_rows {
        j.set(&format!("scalar_div_per_s_{name}"), (*s).into());
        j.set(&format!("kernel_autovec_div_per_s_{name}"), (*av).into());
        // Without a vector engine the kernel's production engine IS the
        // autovec configuration; the simd-vs-autovec ratio is only
        // recorded when a vector engine actually ran — a
        // scalar-vs-scalar ~1.0 would read as "no SIMD win".
        let keff = k.unwrap_or(*av);
        j.set(&format!("kernel_div_per_s_{name}"), keff.into());
        j.set(&format!("kernel_over_scalar_{name}"), (keff / s).into());
        if let Some(k) = k {
            j.set(&format!("simd_over_autovec_{name}"), (k / av).into());
            // AVX-512 boxes additionally record the wide engine under
            // its own per-format key, so the 512-bit rows build their
            // own gated trajectory (on AVX2-only boxes these keys are
            // simply absent and the trend gate prints n/a).
            if simd_engine.name() == "avx512" {
                j.set(&format!("kernel_simd512_div_per_s_{name}"), (*k).into());
            }
        }
    }
    let mut arr = Vec::new();
    for (label, s, bthr) in &rows {
        let mut o = Json::obj();
        o.set("divider", label.as_str().into());
        o.set("scalar_div_per_s", (*s).into());
        o.set("batch_div_per_s", (*bthr).into());
        o.set("batch_over_scalar", (bthr / s).into());
        arr.push(o);
    }
    j.set("batch_vs_scalar", Json::Arr(arr));
    tsdiv::harness::write_bench_json("divider_throughput", &j);

    // Cycle-model comparison — the architectural claim the paper makes.
    let mut t = Table::new(
        "hardware cycle model (f64-grade significand, 15 ps gate)",
        &["unit", "latency cycles", "II", "latency ns"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right]);
    for (label, tm) in [
        ("taylor n=5, ilm 2, iterative", divider_timing(60, 5, 2, false)),
        ("taylor n=5, ilm 2, pipelined (§7)", divider_timing(60, 5, 2, true)),
        ("digit recurrence (1 bit/cycle)", longdiv_timing(52)),
    ] {
        t.row(&[
            label.to_string(),
            tm.latency_cycles.to_string(),
            tm.initiation_interval.to_string(),
            format!("{:.2}", tm.latency_ns(15.0)),
        ]);
    }
    t.print();
    println!(
        "shape check: taylor latency {} cycles < longdiv {} cycles — who-wins matches the paper's motivation",
        divider_timing(60, 5, 2, false).latency_cycles,
        longdiv_timing(52).latency_cycles
    );
    println!("\n(throughput target & perf log: EXPERIMENTS.md §Perf; {} = {})",
        "gold ref", sig(results[4].1 / 1e6, 4));
}
