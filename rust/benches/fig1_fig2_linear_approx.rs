//! E2/E3 — Figures 1 & 2: the single-segment linear approximation of
//! 1/x on [1,2] (eq 13–15) and the m(x) curve (eq 16).

use tsdiv::harness::{timed_section, Report, Verdict};
use tsdiv::pla::{m_max, m_value, optimal_p, pointwise_error, total_error, y0};
use tsdiv::util::table::{sig, Align, Table};

fn main() {
    println!("\n===== E2: Figure 1 — 1/x vs optimal linear approximation on [1,2] =====\n");
    let (a, b) = (1.0, 2.0);

    // The Fig-1 series: x, 1/x, y0(x), pointwise error (eq 13).
    let mut t = Table::new(
        "Fig 1 series (16 of 256 points shown)",
        &["x", "1/x", "y0(x)", "E(x) eq(13)"],
    );
    let p = optimal_p(a, b);
    for i in (0..256).step_by(16) {
        let x = a + (b - a) * (i as f64 + 0.5) / 256.0;
        t.row(&[
            format!("{x:.4}"),
            format!("{:.6}", 1.0 / x),
            format!("{:.6}", y0(x, a, b)),
            sig(pointwise_error(x, p), 4),
        ]);
    }
    t.print();

    let mut report = Report::new("Fig 1/2 analytic checkpoints");
    // Optimal p = (a+b)/2 (eq 14 minimization).
    report.row_num("optimal p for [1,2]", 1.5, p, 1e-12);
    // E_total at optimum (eq 14) is positive and smaller than neighbours.
    let e_opt = total_error(a, b, p);
    report.row(
        "E_total(p=1.5) < E_total(p±0.1)",
        "true",
        &format!(
            "{}",
            e_opt < total_error(a, b, 1.4) && e_opt < total_error(a, b, 1.6)
        ),
        if e_opt < total_error(a, b, 1.4) && e_opt < total_error(a, b, 1.6) {
            Verdict::Match
        } else {
            Verdict::Mismatch
        },
    );
    // Fig 2: m(x,1,2) maximum = 1/9 at x ∈ {1, 2} (paper: eq 18 uses 9/8 & 1/9).
    report.row_num("m_max on [1,2] (paper 1/9)", 1.0 / 9.0, m_max(a, b), 1e-12);
    report.row_num("m(1)", 1.0 / 9.0, m_value(1.0, a, b), 1e-12);
    report.row_num("m(2)", 1.0 / 9.0, m_value(2.0, a, b), 1e-12);
    report.row_num("m(1.5) (midpoint zero)", 0.0, m_value(1.5, a, b), 0.0);
    report.print();

    println!("\n===== E3: Figure 2 — m(x) over [1,2] =====\n");
    let mut t = Table::new("Fig 2 series m(x,1,2)", &["x", "m(x)"]).aligns(&[Align::Right; 2]);
    for i in 0..=16 {
        let x = 1.0 + i as f64 / 16.0;
        t.row(&[format!("{x:.4}"), sig(m_value(x, a, b), 5)]);
    }
    t.print();

    timed_section("m_value over 256-point grid", || {
        let mut acc = 0.0;
        for i in 0..256 {
            let x = 1.0 + (i as f64 + 0.5) / 256.0;
            acc += m_value(x, 1.0, 2.0);
        }
        tsdiv::util::black_box(acc);
    });
    assert_eq!(report.mismatches(), 0);
}
