//! E1 — Table I: regenerate the piecewise-linear segment boundaries for
//! n = 5 and 53-bit precision (paper §3, eq 19/20) and compare against
//! the published values.

use tsdiv::harness::{timed_section, Report, Verdict};
use tsdiv::pla::{derive_segments, segment_bound_log2, PAPER_TABLE_I};
use tsdiv::util::table::{sig, Align, Table};

fn main() {
    println!("\n===== E1: Table I — segment boundaries (n=5, 53-bit) =====\n");
    let bounds = derive_segments(5, 53).expect("Table-I derivation");
    assert_eq!(bounds.len(), 9);

    let mut report = Report::new("Table I: derived vs paper");
    for (i, (&ours, paper)) in bounds[1..].iter().zip(PAPER_TABLE_I).enumerate() {
        let rel = ((ours - paper) / paper).abs();
        // b0 must match tightly; the paper's later entries drift from
        // their own recurrence (eq 20 is scale-invariant → exactly
        // geometric; the published table is not). See DESIGN.md E1.
        let verdict = if rel < 5e-5 {
            Verdict::Match
        } else if rel < 5e-3 {
            Verdict::Consistent
        } else {
            Verdict::Mismatch
        };
        report.row(&format!("b{i}"), &format!("{paper}"), &sig(ours, 6), verdict);
    }
    report.print();

    // The self-consistency view: the recurrence bound at each derived
    // boundary is exactly 2^-53; at the paper's boundaries it varies.
    let mut t = Table::new(
        "eq-(20) bound at each boundary (log2; target −53)",
        &["segment", "derived b", "bound@derived", "paper b", "bound@paper"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    let mut a = 1.0;
    for (i, (&ours, paper)) in bounds[1..].iter().zip(PAPER_TABLE_I).enumerate() {
        t.row(&[
            format!("seg {i}"),
            sig(ours, 6),
            format!("{:.2}", segment_bound_log2(a, ours, 5)),
            format!("{paper}"),
            format!("{:.2}", segment_bound_log2(a, paper, 5)),
        ]);
        a = ours;
    }
    t.print();
    println!(
        "segments derived: {} (paper: 8); constant ratio b_k/b_(k-1) = {:.6}",
        bounds.len() - 1,
        bounds[1]
    );

    let m = timed_section("derive_segments(5, 53)", || {
        let b = derive_segments(5, 53).expect("Table-I derivation");
        tsdiv::util::black_box(b);
    });
    println!(
        "  ({} boundary solves per derivation)\n  throughput: {:.0} derivations/s",
        bounds.len() - 1,
        m.throughput()
    );
    assert_eq!(report.mismatches(), 0, "Table I reproduction failed");
}
