//! E7 — Figure 6: the powering-unit schedule (12 powers), operand-cache
//! effectiveness, and cycles vs a naive chained-multiply unit.

use tsdiv::harness::{timed_section, Report, Verdict};
use tsdiv::hw::powering_timing;
use tsdiv::powering::{schedule_cycles, ExactMul, PoweringUnit};
use tsdiv::util::table::{Align, Table};

fn main() {
    println!("\n===== E7: Fig 6 — powering-unit schedule for 12 powers =====\n");
    const F: u32 = 40;
    let x = (0.83 * (1u64 << F) as f64) as u64;
    let mut be = ExactMul::default();
    let mut pu = PoweringUnit::new(&mut be, F);
    let r = pu.compute_powers(x, 12);

    let mut t = Table::new(
        "executed schedule (one row per cycle)",
        &["cycle", "multiplier (odd powers)", "squaring unit (even powers)"],
    )
    .aligns(&[Align::Right, Align::Left, Align::Left]);
    for c in &r.schedule {
        t.row(&[
            c.cycle.to_string(),
            c.odd_power.map(|p| format!("x^{p} = x^{} · x (cached PE/LOD)", p - 1)).unwrap_or_else(|| "—".into()),
            c.even_power.map(|p| format!("x^{p} = (x^{})²", p / 2)).unwrap_or_else(|| "—".into()),
        ]);
    }
    t.print();

    let mut report = Report::new("Fig 6 schedule invariants");
    report.row(
        "12 powers in 6 cycles (Fig 6)",
        "6",
        &r.cycles.to_string(),
        if r.cycles == 6 { Verdict::Match } else { Verdict::Mismatch },
    );
    report.row(
        "squares : multiplies",
        "6 : 5",
        &format!("{} : {}", r.counts.squares, r.counts.muls),
        if r.counts.squares == 6 && r.counts.muls == 5 { Verdict::Match } else { Verdict::Mismatch },
    );
    report.row(
        "PE evaluations saved by §6 cache",
        "1 per odd power (5)",
        &r.counts.pe_cache_hits.to_string(),
        if r.counts.pe_cache_hits == 5 { Verdict::Match } else { Verdict::Mismatch },
    );
    // Naive unit: chained multiplies x^(k+1) = x^k·x → 11 sequential
    // multiplies, two PE per multiply, no parallel squarer.
    report.row(
        "cycles vs naive chained multiplies",
        "6 vs 11",
        &format!("{} vs 11", r.cycles),
        if r.cycles < 11 { Verdict::Match } else { Verdict::Mismatch },
    );
    report.print();

    // Cycles scale: schedule_cycles closed form vs executed for 2..=16.
    let mut t = Table::new(
        "powers ↔ cycles (closed form; naive = P−1)",
        &["max power", "Fig-6 cycles", "naive cycles", "speedup"],
    )
    .aligns(&[Align::Right; 4]);
    for p in [2u32, 4, 6, 8, 12, 16] {
        let c = schedule_cycles(p);
        t.row(&[
            p.to_string(),
            c.to_string(),
            (p - 1).to_string(),
            format!("{:.2}×", (p - 1) as f64 / c as f64),
        ]);
    }
    t.print();

    // Wall-clock timing estimate from the hw model (iterative vs pipelined).
    let mut t = Table::new(
        "powering-unit timing estimate (w=53, 2 ILM corrections, 15 ps gate)",
        &["mode", "latency (cycles)", "II", "latency ns", "results/s"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    for (label, pipelined) in [("iterative", false), ("pipelined (§7)", true)] {
        let tm = powering_timing(53, 12, 2, pipelined);
        t.row(&[
            label.to_string(),
            tm.latency_cycles.to_string(),
            tm.initiation_interval.to_string(),
            format!("{:.2}", tm.latency_ns(15.0)),
            format!("{:.2e}", tm.throughput_per_s(15.0)),
        ]);
    }
    t.print();

    timed_section("compute_powers(x, 12) word-level model", || {
        let mut be = ExactMul::default();
        let mut pu = PoweringUnit::new(&mut be, F);
        tsdiv::util::black_box(pu.compute_powers(tsdiv::util::black_box(x), 12));
    });
    assert_eq!(report.mismatches(), 0);
}
