//! E10 — coordinator/service benchmark (architecture layer): throughput
//! and latency of the batched division service across worker counts,
//! batch budgets, and backends (native vs PJRT when artifacts exist).

use std::time::{Duration, Instant};

use tsdiv::coordinator::{BackendChoice, DivRequest, DivisionService, ServiceConfig, SubmitError};
use tsdiv::fp::{Format, Op, Rounding, ALL_FORMATS, F32};
use tsdiv::harness::gen_bits_batch;
use tsdiv::runtime::artifacts_available;
use tsdiv::util::json::Json;
use tsdiv::util::rng::Rng;
use tsdiv::util::table::{sig, Align, Table};

/// Closed-loop load: `clients` threads each keep one request in flight,
/// cycling through `formats` (one entry = homogeneous traffic).
fn run_load_formats(
    backend: BackendChoice,
    workers: usize,
    max_batch: usize,
    clients: usize,
    lanes: usize,
    formats: &'static [Format],
    duration: Duration,
) -> (f64, f64, f64, f64, f64) {
    let svc = std::sync::Arc::new(
        DivisionService::start(
            ServiceConfig {
                workers,
                max_batch,
                max_wait: Duration::from_micros(200),
                queue_capacity: 1 << 14,
                ..ServiceConfig::default()
            },
            backend,
        )
        .expect("service"),
    );
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut handles = Vec::new();
    for cid in 0..clients {
        let svc = std::sync::Arc::clone(&svc);
        let stop = std::sync::Arc::clone(&stop);
        handles.push(std::thread::spawn(move || {
            let mut lanes_done = 0u64;
            let mut req_no = 0u64;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                let fmt = formats[(req_no % formats.len() as u64) as usize];
                let (a, b) = gen_bits_batch(fmt, lanes, 8, cid as u64 * 1000 + req_no);
                req_no += 1;
                match svc.submit_request(DivRequest::new(
                    fmt,
                    tsdiv::fp::Rounding::NearestEven,
                    a,
                    b,
                )) {
                    Ok(t) => {
                        t.wait().expect("division");
                        lanes_done += lanes as u64;
                    }
                    Err(SubmitError::Busy) => std::thread::yield_now(),
                    Err(e) => panic!("{e}"),
                }
            }
            lanes_done
        }));
    }
    let t0 = Instant::now();
    std::thread::sleep(duration);
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let dt = t0.elapsed().as_secs_f64();
    let m = svc.metrics();
    let out = (
        total as f64 / dt,
        m.latency_p50 * 1e3,
        m.latency_p99 * 1e3,
        m.mean_batch_lanes(),
        m.mean_batch_cost(),
    );
    match std::sync::Arc::try_unwrap(svc) {
        Ok(s) => s.shutdown(),
        Err(_) => {}
    }
    out
}

/// Divisor rows per scale-by-recip request: 256 lanes split into 8
/// rows of 32, so every request straddles pipeline tiles and the
/// broadcast path is actually exercised.
const SCALE_ROWS: usize = 8;

/// Closed-loop per-op load on f32/nearest traffic: `clients` threads
/// each keep one typed request of `lanes` lanes in flight. Returns
/// (lanes/s, p50 ms, p99 ms).
fn run_load_op(
    backend: BackendChoice,
    op: Op,
    clients: usize,
    lanes: usize,
    duration: Duration,
) -> (f64, f64, f64) {
    let svc = std::sync::Arc::new(
        DivisionService::start(
            ServiceConfig {
                workers: 2,
                max_batch: 4096,
                max_wait: Duration::from_micros(200),
                queue_capacity: 1 << 14,
                ..ServiceConfig::default()
            },
            backend,
        )
        .expect("service"),
    );
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut handles = Vec::new();
    for cid in 0..clients {
        let svc = std::sync::Arc::clone(&svc);
        let stop = std::sync::Arc::clone(&stop);
        handles.push(std::thread::spawn(move || {
            let mut lanes_done = 0u64;
            let mut req_no = 0u64;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                let (a, b) = gen_bits_batch(F32, lanes, 8, cid as u64 * 1000 + req_no);
                req_no += 1;
                let req = match op {
                    Op::Div => DivRequest::new(F32, Rounding::NearestEven, a, b),
                    Op::Recip => DivRequest::recip(F32, Rounding::NearestEven, a),
                    Op::Rsqrt => {
                        // rsqrt of a negative is NaN fill, not refinement.
                        let mut xs = a;
                        for x in xs.iter_mut() {
                            *x &= !F32.sign_mask();
                        }
                        DivRequest::rsqrt(F32, Rounding::NearestEven, xs)
                    }
                    Op::ScaleByRecip => DivRequest::scale_by_recip(
                        F32,
                        Rounding::NearestEven,
                        a,
                        b[..SCALE_ROWS].to_vec(),
                    ),
                };
                match svc.submit_request(req) {
                    Ok(t) => {
                        t.wait().expect("typed op");
                        lanes_done += lanes as u64;
                    }
                    Err(SubmitError::Busy) => std::thread::yield_now(),
                    Err(e) => panic!("{e}"),
                }
            }
            lanes_done
        }));
    }
    let t0 = Instant::now();
    std::thread::sleep(duration);
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let dt = t0.elapsed().as_secs_f64();
    let m = svc.metrics();
    let out = (total as f64 / dt, m.latency_p50 * 1e3, m.latency_p99 * 1e3);
    if let Ok(s) = std::sync::Arc::try_unwrap(svc) {
        s.shutdown()
    }
    out
}

/// f32-only closed-loop load (the original shape of this bench).
fn run_load(
    backend: BackendChoice,
    workers: usize,
    max_batch: usize,
    clients: usize,
    lanes: usize,
    duration: Duration,
) -> (f64, f64, f64, f64, f64) {
    static F32_ONLY: [Format; 1] = [F32];
    run_load_formats(backend, workers, max_batch, clients, lanes, &F32_ONLY, duration)
}

fn main() {
    println!("\n===== E10: coordinator — batched division service =====\n");
    let quick = std::env::var("TSDIV_BENCH_QUICK").is_ok_and(|v| v == "1");
    let dur = if quick {
        Duration::from_millis(250)
    } else {
        Duration::from_millis(900)
    };

    let mut t = Table::new(
        "native backend: throughput vs (workers × max_batch), 8 clients × 64 lanes",
        &["workers", "max batch", "div/s", "p50 ms", "p99 ms", "lanes/batch"],
    )
    .aligns(&[Align::Right; 6]);
    for workers in [1usize, 2, 4] {
        for max_batch in [256usize, 1024, 4096] {
            let (thr, p50, p99, lpb, _) = run_load(
                BackendChoice::Native {
                    order: 5,
                    ilm_iterations: None,
                },
                workers,
                max_batch,
                8,
                64,
                dur,
            );
            t.row(&[
                workers.to_string(),
                max_batch.to_string(),
                sig(thr, 4),
                format!("{p50:.3}"),
                format!("{p99:.3}"),
                format!("{lpb:.1}"),
            ]);
        }
    }
    t.print();

    if artifacts_available() {
        let mut t = Table::new(
            "PJRT backend (AOT JAX/Pallas artifact), 8 clients × 256 lanes",
            &["workers", "div/s", "p50 ms", "p99 ms", "lanes/batch"],
        )
        .aligns(&[Align::Right; 5]);
        for workers in [1usize, 2] {
            let (thr, p50, p99, lpb, _) =
                run_load(BackendChoice::Pjrt, workers, 4096, 8, 256, dur);
            t.row(&[
                workers.to_string(),
                sig(thr, 4),
                format!("{p50:.3}"),
                format!("{p99:.3}"),
                format!("{lpb:.1}"),
            ]);
        }
        t.print();
        println!("(PJRT p99 includes first-batch executable warmup)");
    } else {
        println!("PJRT backend skipped: run `make artifacts` first.");
    }

    // Worker datapaths through the full service stack: identical
    // coordinator, identical load, only the worker's division loop
    // differs — the staged SoA kernel driven directly (Kernel, on the
    // auto-resolved lane engine), the same kernel pinned to the scalar
    // lane engine ("autovec" — what the compiler makes of the stage
    // loops), the kernel behind divisor grouping (Native), and the
    // per-lane scalar loop (NativeScalar).
    // Force the vector engine when available so the simd row can never
    // silently measure the scalar fallback; on a host without a vector
    // engine the row pins (and labels) the scalar engine and the
    // simd/autovec ratio is not recorded.
    let simd_on = tsdiv::simd::simd_available();
    let kernel_simd = if simd_on {
        tsdiv::simd::SimdChoice::Forced
    } else {
        tsdiv::simd::SimdChoice::Scalar
    };
    let simd_engine = kernel_simd.resolve_lenient();
    let mut t = Table::new(
        &format!(
            "worker datapath: kernel(simd={}) vs kernel(autovec) vs batched vs scalar \
             (2 workers, 8 clients × 256 lanes)",
            simd_engine.name()
        ),
        &["datapath", "div/s", "p50 ms", "p99 ms", "lanes/batch"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    let mut pair: Vec<(&str, f64)> = Vec::new();
    for (label, backend) in [
        (
            "batched (native)",
            BackendChoice::Native {
                order: 5,
                ilm_iterations: None,
            },
        ),
        (
            "scalar",
            BackendChoice::NativeScalar {
                order: 5,
                ilm_iterations: None,
            },
        ),
        (
            "kernel (staged SoA, simd)",
            BackendChoice::Kernel {
                order: 5,
                kernel: tsdiv::kernel::KernelConfig {
                    simd: kernel_simd,
                    ..tsdiv::kernel::KernelConfig::default()
                },
            },
        ),
        (
            "kernel (staged SoA, autovec)",
            BackendChoice::Kernel {
                order: 5,
                kernel: tsdiv::kernel::KernelConfig {
                    simd: tsdiv::simd::SimdChoice::Scalar,
                    ..tsdiv::kernel::KernelConfig::default()
                },
            },
        ),
    ] {
        let (thr, p50, p99, lpb, _) = run_load(backend, 2, 4096, 8, 256, dur);
        pair.push((label, thr));
        t.row(&[
            label.to_string(),
            sig(thr, 4),
            format!("{p50:.3}"),
            format!("{p99:.3}"),
            format!("{lpb:.1}"),
        ]);
    }
    t.print();
    let speedup = pair[0].1 / pair[1].1;
    let kernel_speedup = pair[2].1 / pair[1].1;
    let simd_over_autovec = pair[2].1 / pair[3].1;
    println!("batched/scalar service throughput: {speedup:.2}x");
    println!("kernel/scalar  service throughput: {kernel_speedup:.2}x");
    if simd_on {
        println!("kernel simd/autovec  throughput:   {simd_over_autovec:.2}x\n");
    } else {
        println!("kernel simd/autovec  throughput:   n/a (no vector engine on this host)\n");
    }

    // Multi-format traffic through the typed request API: homogeneous
    // loads per format, then the interleaved mix (which the batcher must
    // keep coalescing by (Op, Format, Rounding) key).
    let mut t = Table::new(
        "typed requests: throughput by format, cost-weighted budgets (2 workers, 8 clients × 256 lanes)",
        &["traffic", "div/s", "p50 ms", "p99 ms", "lanes/batch", "cost/batch"],
    )
    .aligns(&[
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    let native = BackendChoice::Native {
        order: 5,
        ilm_iterations: None,
    };
    let mut mixed_thr = 0.0;
    let mut mixed_cost_per_batch = 0.0;
    static SINGLE: [[Format; 1]; 4] = [
        [tsdiv::fp::F16],
        [tsdiv::fp::BF16],
        [tsdiv::fp::F32],
        [tsdiv::fp::F64],
    ];
    static MIXED: [Format; 4] = ALL_FORMATS;
    for (label, formats) in [
        ("f16", &SINGLE[0][..]),
        ("bf16", &SINGLE[1][..]),
        ("f32", &SINGLE[2][..]),
        ("f64", &SINGLE[3][..]),
        ("mixed (all four)", &MIXED[..]),
    ] {
        let (thr, p50, p99, lpb, cpb) = run_load_formats(native, 2, 4096, 8, 256, formats, dur);
        if label.starts_with("mixed") {
            mixed_thr = thr;
            mixed_cost_per_batch = cpb;
        }
        t.row(&[
            label.to_string(),
            sig(thr, 4),
            format!("{p50:.3}"),
            format!("{p99:.3}"),
            format!("{lpb:.1}"),
            format!("{cpb:.1}"),
        ]);
    }
    t.print();

    // The Goldschmidt datapath per format, plus the adaptive router on
    // the mixed load: same coordinator, same traffic shapes as the
    // typed-request rows above, so the goldschmidt_div_per_s_{fmt} keys
    // are directly comparable against the kernel/native rows and the
    // router row measures routed end-to-end throughput.
    let goldschmidt = BackendChoice::Goldschmidt {
        iterations: 3,
        kernel: tsdiv::kernel::KernelConfig::default(),
        trunc_bits: 0,
    };
    let mut t = Table::new(
        "goldschmidt datapath + adaptive router (2 workers, 8 clients × 256 lanes)",
        &["traffic", "div/s", "p50 ms", "p99 ms", "lanes/batch"],
    )
    .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    let mut goldschmidt_thr: Vec<(&str, f64)> = Vec::new();
    for (label, formats) in [
        ("goldschmidt f16", &SINGLE[0][..]),
        ("goldschmidt bf16", &SINGLE[1][..]),
        ("goldschmidt f32", &SINGLE[2][..]),
        ("goldschmidt f64", &SINGLE[3][..]),
    ] {
        let (thr, p50, p99, lpb, _) =
            run_load_formats(goldschmidt, 2, 4096, 8, 256, formats, dur);
        goldschmidt_thr.push((label.rsplit(' ').next().unwrap(), thr));
        t.row(&[
            label.to_string(),
            sig(thr, 4),
            format!("{p50:.3}"),
            format!("{p99:.3}"),
            format!("{lpb:.1}"),
        ]);
    }
    let (auto_thr, auto_p50, auto_p99, auto_lpb, _) =
        run_load_formats(BackendChoice::Auto, 2, 4096, 8, 256, &MIXED, dur);
    t.row(&[
        "auto (router, mixed)".to_string(),
        sig(auto_thr, 4),
        format!("{auto_p50:.3}"),
        format!("{auto_p99:.3}"),
        format!("{auto_lpb:.1}"),
    ]);
    t.print();

    // Typed fused ops through both kernel datapaths on f32/nearest
    // traffic. Every (op, backend) lanes/s row is a router per-op
    // history seed ({op.key_name()}_div_per_s_{backend} — underscore
    // spelling, so scale-by-recip emits scale_recip_*); scale-by-recip
    // is additionally reported in rows/s — each row is one reciprocal
    // inverted once and broadcast across its 32 lanes.
    let kernel = BackendChoice::Kernel {
        order: 5,
        kernel: tsdiv::kernel::KernelConfig::default(),
    };
    let mut t = Table::new(
        "typed fused ops: kernel vs goldschmidt (2 workers, 8 clients × 256 lanes, f32)",
        &["op", "backend", "lanes/s", "p50 ms", "p99 ms"],
    )
    .aligns(&[
        Align::Left,
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    let mut op_thr: Vec<(Op, &str, f64)> = Vec::new();
    for &(op, backend_label, backend) in &[
        (Op::Recip, "kernel", kernel),
        (Op::Recip, "goldschmidt", goldschmidt),
        (Op::Rsqrt, "kernel", kernel),
        (Op::Rsqrt, "goldschmidt", goldschmidt),
        (Op::ScaleByRecip, "kernel", kernel),
        (Op::ScaleByRecip, "goldschmidt", goldschmidt),
    ] {
        let (thr, p50, p99) = run_load_op(backend, op, 8, 256, dur);
        op_thr.push((op, backend_label, thr));
        t.row(&[
            op.name().to_string(),
            backend_label.to_string(),
            sig(thr, 4),
            format!("{p50:.3}"),
            format!("{p99:.3}"),
        ]);
    }
    t.print();

    // Worker-scaling sweep on mixed-format traffic (the ROADMAP's
    // near-linear-scaling exit criterion): default sharding (one shard
    // per worker), stealing enabled, saturating closed-loop clients.
    let mut t = Table::new(
        "worker scaling: sharded runtime on mixed-format traffic (8 clients × 256 lanes)",
        &["workers", "shards", "div/s", "scale vs w=1", "p50 ms", "p99 ms", "lanes/batch"],
    )
    .aligns(&[Align::Right; 7]);
    let mut scale_rows: Vec<(usize, f64)> = Vec::new();
    let mut scale_p99_ms = 0.0;
    for workers in [1usize, 2, 4, 8] {
        let (thr, p50, p99, lpb, _) = run_load_formats(native, workers, 4096, 8, 256, &MIXED, dur);
        let base = scale_rows.first().map_or(thr, |&(_, t1)| t1);
        scale_rows.push((workers, thr));
        scale_p99_ms = p99; // keep the most-parallel run's tail
        t.row(&[
            workers.to_string(),
            workers.to_string(),
            sig(thr, 4),
            format!("{:.2}x", thr / base),
            format!("{p50:.3}"),
            format!("{p99:.3}"),
            format!("{lpb:.1}"),
        ]);
    }
    t.print();

    // Record the comparison for the bench trajectory.
    let mut j = Json::obj();
    j.set("bench", "coordinator_serve".into());
    j.set("workers", 2u64.into());
    j.set("clients", 8u64.into());
    j.set("request_lanes", 256u64.into());
    j.set("batched_div_per_s", pair[0].1.into());
    j.set("scalar_div_per_s", pair[1].1.into());
    j.set("kernel_div_per_s", pair[2].1.into());
    j.set("kernel_autovec_div_per_s", pair[3].1.into());
    j.set("batched_over_scalar", speedup.into());
    j.set("kernel_over_scalar", kernel_speedup.into());
    // Only meaningful when the vector engine actually ran.
    if simd_on {
        j.set("kernel_simd_over_autovec", simd_over_autovec.into());
    }
    j.set("simd_engine", simd_engine.name().into());
    j.set("mixed_format_div_per_s", mixed_thr.into());
    // Cost units per emitted batch under the mixed load — how close the
    // cost-weighted assembler runs to its budget across the format mix.
    j.set("mixed_format_cost_per_batch", mixed_cost_per_batch.into());
    // Scaling rows: per-worker-count throughput (higher-is-better gate
    // keys) plus the most-parallel run's p99 tail in microseconds,
    // which the direction-aware gate judges lower-is-better.
    for &(workers, thr) in &scale_rows {
        j.set(&format!("serve_scale_w{workers}_div_per_s"), thr.into());
    }
    j.set("serve_p99_latency_us", (scale_p99_ms * 1e3).into());
    // The second datapath and the router, under the direction-aware
    // gate from their first CI run (per_s keys judge higher-is-better).
    for &(fmt_name, thr) in &goldschmidt_thr {
        j.set(&format!("goldschmidt_div_per_s_{fmt_name}"), thr.into());
    }
    j.set("router_auto_div_per_s", auto_thr.into());
    // Per-op rows: every (op, backend) pair emits a lanes/s key spelled
    // with `Op::key_name()` — the exact keys `seed_from_history` looks
    // up, so the bench and the router cannot drift apart (the old
    // hyphen/underscore split left scale-recip cells permanently
    // unseeded). The fused scale-by-recip additionally reports rows/s
    // from the kernel row (one reciprocal broadcast per row), kept for
    // gate continuity. All carry the per_s suffix, so the
    // direction-aware gate judges them higher-is-better — and prints
    // n/a against history predating the op axis instead of failing.
    for &(op, backend_label, thr) in &op_thr {
        j.set(
            &format!("{}_div_per_s_{}", op.key_name(), backend_label),
            thr.into(),
        );
        if op == Op::ScaleByRecip && backend_label == "kernel" {
            j.set(
                "scale_recip_rows_per_s",
                (thr * SCALE_ROWS as f64 / 256.0).into(),
            );
        }
    }
    tsdiv::harness::write_bench_json("coordinator_serve", &j);

    // Coordinator overhead: service vs bare loop over IDENTICAL
    // pre-generated operands (on a single-core machine the client
    // threads' operand *generation* would otherwise be misattributed
    // to the coordinator).
    let bare = {
        use tsdiv::divider::{Divider, TaylorDivider};
        let mut d = TaylorDivider::paper_exact();
        let mut rng = Rng::new(1);
        let a: Vec<f32> = (0..65536).map(|_| rng.f32_log_uniform(-8, 8)).collect();
        let b: Vec<f32> = (0..65536).map(|_| rng.f32_log_uniform(-8, 8)).collect();
        let t0 = Instant::now();
        let mut acc = 0u32;
        for i in 0..a.len() {
            acc ^= d.div_f32(a[i], b[i]).to_bits();
        }
        tsdiv::util::black_box(acc);
        a.len() as f64 / t0.elapsed().as_secs_f64()
    };
    let svc_thr = {
        let svc = DivisionService::start(
            ServiceConfig {
                workers: 1,
                max_batch: 4096,
                max_wait: Duration::from_micros(200),
                queue_capacity: 1 << 14,
                ..ServiceConfig::default()
            },
            BackendChoice::Native {
                order: 5,
                ilm_iterations: None,
            },
        )
        .expect("service");
        let mut rng = Rng::new(1);
        // Pre-generate 64 requests of 1024 lanes; clone per submission
        // (an 8 KiB memcpy, ≪ the 65 µs of compute it buys).
        let reqs: Vec<DivRequest> = (0..64)
            .map(|_| {
                let a: Vec<f32> = (0..1024).map(|_| rng.f32_log_uniform(-8, 8)).collect();
                let b: Vec<f32> = (0..1024).map(|_| rng.f32_log_uniform(-8, 8)).collect();
                DivRequest::from_f32(&a, &b)
            })
            .collect();
        let t0 = Instant::now();
        let mut lanes = 0u64;
        while t0.elapsed() < Duration::from_millis(800) {
            // Keep 4 requests in flight (double buffering through the
            // batcher) without extra client threads.
            let tickets: Vec<_> = reqs
                .iter()
                .take(4)
                .map(|req| svc.submit_request(req.clone()).expect("submit"))
                .collect();
            for t in tickets {
                t.wait().expect("divide");
                lanes += 1024;
            }
        }
        let thr = lanes as f64 / t0.elapsed().as_secs_f64();
        svc.shutdown();
        thr
    };
    println!(
        "\ncoordinator overhead: bare loop {:.2} Mdiv/s vs 1-worker service {:.2} Mdiv/s ({:.1} % overhead)",
        bare / 1e6,
        svc_thr / 1e6,
        (1.0 - svc_thr / bare) * 100.0
    );
}
