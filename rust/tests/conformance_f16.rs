//! Exhaustive binary16 conformance: every one of the 2^16 divisor bit
//! patterns, against a fixed dividend set that covers every IEEE class,
//! through the service's `BackendChoice::Kernel` worker vs the
//! exactly-rounded `Gold` (longdiv) backend — per rounding mode.
//!
//! The contract being locked down is the one the property tests sample:
//! special lanes (resolved by the shared `prepare()` path) are
//! **bit-identical** to gold, and finite lanes stay inside the Taylor
//! unit's documented ≤ 2-ulp band. f16 is the one format small enough
//! to sweep *completely*, so this test closes the sampling gap for the
//! format the qr workload ships over the wire.
//!
//! The full sweep is ~4.5 M divisions per backend and is `#[ignore]`d
//! by default; CI runs it as its own step:
//!
//! ```bash
//! cargo test --release --test conformance_f16 -- --ignored
//! ```
//!
//! A subsampled smoke sweep (every 251st pattern) runs with the normal
//! suite so the harness itself cannot bitrot.
//!
//! The unary ops get the same treatment: `Recip` and `Rsqrt` sweep all
//! 2^16 *operand* patterns per rounding mode through the kernel and
//! Goldschmidt datapaths vs gold, with the per-op special rules
//! (`Recip`: NaN/Inf/zero operands; `Rsqrt`: those plus any negative).

use tsdiv::coordinator::{Backend, BackendChoice};
use tsdiv::divider::{prepare, Prepared};
use tsdiv::fp::{ulp_diff, unpack, Class, Op, Rounding, F16};
use tsdiv::harness::special_patterns;
use tsdiv::kernel::KernelConfig;

/// The fixed dividend set: the full special menu (NaN, ±Inf, ±0,
/// smallest/largest subnormal, 1.0, max finite) plus finite probes —
/// negatives, an exact power of two, a non-trivial significand, the
/// smallest normal on both signs, and a near-overflow value.
fn dividends() -> Vec<u64> {
    let mut d: Vec<u64> = special_patterns(F16).to_vec();
    d.extend([
        0xBC00, // -1.0
        0x4000, // 2.0
        0x3555, // ~0.3333
        0x4248, // ~3.14
        0x0400, // smallest positive normal
        0x8400, // smallest negative normal
        0x7BFE, // just below +max finite
        0xB266, // ~-0.2
    ]);
    d
}

/// One full-divisor-range pass: `dividend / every_divisor` through both
/// backends, checking the conformance contract lane by lane. `stride`
/// subsamples the divisor space (1 = exhaustive). Returns the largest
/// finite deviation seen (in ulp).
fn sweep(stride: u64) -> u64 {
    let mut kern = BackendChoice::Kernel {
        order: 5,
        kernel: KernelConfig::default(),
    }
    .build()
    .expect("kernel backend");
    let mut gold = BackendChoice::Gold.build().expect("gold backend");
    let divisors: Vec<u64> = (0u64..=0xFFFF).step_by(stride as usize).collect();
    let mut max_ulp = 0u64;
    for rm in Rounding::ALL {
        for &a in &dividends() {
            let av = vec![a; divisors.len()];
            let qk = kern.divide(&av, &divisors, F16, rm).expect("kernel divide");
            let qg = gold.divide(&av, &divisors, F16, rm).expect("gold divide");
            for (i, (&k, &g)) in qk.iter().zip(qg.iter()).enumerate() {
                let b = divisors[i];
                let special = matches!(prepare(a, b, F16), Prepared::Done(_));
                match ulp_diff(k, g, F16) {
                    Some(u) if special => assert_eq!(
                        k, g,
                        "special lane {a:#06x}/{b:#06x} ({rm:?}) not bit-identical: \
                         kernel {k:#06x} vs gold {g:#06x} ({u} ulp)"
                    ),
                    Some(u) => {
                        assert!(
                            u <= 2,
                            "finite lane {a:#06x}/{b:#06x} ({rm:?}) outside the ≤2-ulp \
                             band: kernel {k:#06x} vs gold {g:#06x} ({u} ulp)"
                        );
                        max_ulp = max_ulp.max(u);
                    }
                    None => assert!(
                        unpack(k, F16).class == Class::NaN && unpack(g, F16).class == Class::NaN,
                        "NaN mismatch at {a:#06x}/{b:#06x} ({rm:?}): \
                         kernel {k:#06x} vs gold {g:#06x}"
                    ),
                }
            }
        }
    }
    max_ulp
}

/// The exhaustive pass: all 65 536 divisor patterns × every rounding
/// mode × the fixed dividend set. CI runs this with `-- --ignored`.
#[test]
#[ignore = "exhaustive 2^16 divisor sweep (~4.5M divisions/backend); run: cargo test --release --test conformance_f16 -- --ignored"]
fn conformance_f16_every_divisor_pattern_vs_gold() {
    let max_ulp = sweep(1);
    println!("f16 conformance: all 2^16 divisors × 4 modes swept; max finite deviation {max_ulp} ulp");
}

/// Subsampled smoke pass (every 251st divisor pattern — prime, so the
/// sample walks the exponent/significand grid) that keeps this harness
/// compiling and honest inside the regular suite.
#[test]
fn conformance_f16_subsampled_smoke() {
    let max_ulp = sweep(251);
    assert!(max_ulp <= 2);
}

/// One unary-op pass over the f16 operand space at `stride`, through
/// the kernel *and* Goldschmidt datapaths vs gold, per rounding mode.
/// Returns the largest finite deviation seen (in ulp).
fn sweep_unary(op: Op, stride: u64) -> u64 {
    let mut kern = BackendChoice::Kernel {
        order: 5,
        kernel: KernelConfig::default(),
    }
    .build()
    .expect("kernel backend");
    let mut gs = BackendChoice::Goldschmidt {
        iterations: 3,
        kernel: KernelConfig::default(),
        trunc_bits: 0,
    }
    .build()
    .expect("goldschmidt backend");
    let mut gold = BackendChoice::Gold.build().expect("gold backend");
    let xs: Vec<u64> = (0u64..=0xFFFF).step_by(stride as usize).collect();
    let mut max_ulp = 0u64;
    for rm in Rounding::ALL {
        let qg = gold.compute(op, &xs, &[], &[], F16, rm).expect("gold compute");
        for (label, be) in [("kernel", &mut kern), ("goldschmidt", &mut gs)] {
            let q = be.compute(op, &xs, &[], &[], F16, rm).expect("unary compute");
            for (i, (&k, &g)) in q.iter().zip(qg.iter()).enumerate() {
                let x = xs[i];
                let u = unpack(x, F16);
                let special_class = matches!(u.class, Class::NaN | Class::Inf | Class::Zero);
                let special = match op {
                    Op::Rsqrt => u.sign || special_class,
                    _ => special_class,
                };
                match ulp_diff(k, g, F16) {
                    Some(du) if special => assert_eq!(
                        k, g,
                        "special {op:?} lane {x:#06x} ({rm:?}) not bit-identical: \
                         {label} {k:#06x} vs gold {g:#06x} ({du} ulp)"
                    ),
                    Some(du) => {
                        assert!(
                            du <= 2,
                            "finite {op:?} lane {x:#06x} ({rm:?}) outside the ≤2-ulp \
                             band: {label} {k:#06x} vs gold {g:#06x} ({du} ulp)"
                        );
                        max_ulp = max_ulp.max(du);
                    }
                    None => assert!(
                        unpack(k, F16).class == Class::NaN && unpack(g, F16).class == Class::NaN,
                        "NaN mismatch at {op:?} {x:#06x} ({rm:?}): \
                         {label} {k:#06x} vs gold {g:#06x}"
                    ),
                }
            }
        }
    }
    max_ulp
}

/// Exhaustive reciprocal: all 2^16 operand patterns × every rounding
/// mode, both kernel datapaths vs gold. CI runs this with `-- --ignored`.
#[test]
#[ignore = "exhaustive 2^16 recip sweep; run: cargo test --release --test conformance_f16 -- --ignored"]
fn conformance_f16_recip_every_pattern_vs_gold() {
    let max_ulp = sweep_unary(Op::Recip, 1);
    println!("f16 recip conformance: all 2^16 operands × 4 modes swept; max {max_ulp} ulp");
}

/// Exhaustive reciprocal square root, same shape as the recip sweep.
#[test]
#[ignore = "exhaustive 2^16 rsqrt sweep; run: cargo test --release --test conformance_f16 -- --ignored"]
fn conformance_f16_rsqrt_every_pattern_vs_gold() {
    let max_ulp = sweep_unary(Op::Rsqrt, 1);
    println!("f16 rsqrt conformance: all 2^16 operands × 4 modes swept; max {max_ulp} ulp");
}

/// Subsampled unary smoke (both ops, every 251st pattern) inside the
/// regular suite.
#[test]
fn conformance_f16_unary_subsampled_smoke() {
    assert!(sweep_unary(Op::Recip, 251) <= 2);
    assert!(sweep_unary(Op::Rsqrt, 251) <= 2);
}
