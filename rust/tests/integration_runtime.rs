//! Integration: AOT artifacts → PJRT → numerics, end to end.
//!
//! Requires `make artifacts`; every test skips with a notice otherwise
//! so `cargo test` stays green on a fresh checkout.

use tsdiv::runtime::{artifacts_available, DivideEngine, Manifest};
use tsdiv::util::rng::Rng;

fn engine_or_skip() -> Option<DivideEngine> {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(DivideEngine::load_default().expect("artifacts present but engine failed to load"))
}

#[test]
fn manifest_lists_divide_entries() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    let m = Manifest::load(&Manifest::default_dir()).unwrap();
    let divides: Vec<_> = m.entries.iter().filter(|e| e.kind == "divide").collect();
    assert!(divides.len() >= 3, "expected ≥3 divide batch sizes");
    for e in &m.entries {
        assert!(e.path.exists(), "missing artifact {}", e.path.display());
    }
}

#[test]
fn engine_divides_exact_batch() {
    let Some(engine) = engine_or_skip() else { return };
    let sizes = engine.batch_sizes();
    assert!(sizes.contains(&1024));
    let n = sizes[0];
    let a: Vec<f32> = (0..n).map(|i| (i + 1) as f32).collect();
    let b: Vec<f32> = (0..n).map(|i| ((i % 9) + 1) as f32).collect();
    let q = engine.divide(&a, &b).unwrap();
    assert_eq!(q.len(), n);
    for i in 0..n {
        let want = a[i] / b[i];
        let ulp = (q[i].to_bits() as i64 - want.to_bits() as i64).unsigned_abs();
        assert!(ulp <= 1, "lane {i}: {} vs {want} ({ulp} ulps)", q[i]);
    }
}

#[test]
fn engine_pads_ragged_batches() {
    let Some(engine) = engine_or_skip() else { return };
    for n in [1usize, 7, 255, 257, 1000, 1025, 5000] {
        let mut rng = Rng::new(n as u64);
        let a: Vec<f32> = (0..n).map(|_| rng.f32_log_uniform(-10, 10)).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.f32_log_uniform(-10, 10)).collect();
        let q = engine.divide(&a, &b).unwrap();
        assert_eq!(q.len(), n, "n={n}");
        for i in 0..n {
            let want = a[i] / b[i];
            let ulp = (q[i].to_bits() as i64 - want.to_bits() as i64).unsigned_abs();
            assert!(ulp <= 1, "n={n} lane {i}: {} vs {want}", q[i]);
        }
    }
}

#[test]
fn engine_handles_specials_like_ieee() {
    let Some(engine) = engine_or_skip() else { return };
    let a = vec![1.0f32, -1.0, 0.0, f32::INFINITY, f32::NAN, 0.0, 3.0, f32::INFINITY];
    let b = vec![0.0f32, 0.0, 0.0, f32::INFINITY, 1.0, 5.0, f32::INFINITY, 2.0];
    let mut pa = a.clone();
    let mut pb = b.clone();
    pa.resize(256, 1.0);
    pb.resize(256, 1.0);
    let q = engine.divide(&pa, &pb).unwrap();
    let want: Vec<f32> = a.iter().zip(&b).map(|(&x, &y)| x / y).collect();
    for i in 0..a.len() {
        if want[i].is_nan() {
            assert!(q[i].is_nan(), "lane {i}: {} want NaN", q[i]);
        } else {
            assert_eq!(q[i].to_bits(), want[i].to_bits(), "lane {i}");
        }
    }
}

#[test]
fn engine_agrees_with_native_datapath() {
    // The two implementations of the same paper architecture (bit-exact
    // Rust vs f32 JAX/Pallas) must agree to ≤1 ulp on normals.
    let Some(engine) = engine_or_skip() else { return };
    use tsdiv::divider::{Divider, TaylorDivider};
    let mut native = TaylorDivider::paper_exact();
    let mut rng = Rng::new(77);
    let n = 1024;
    let a: Vec<f32> = (0..n).map(|_| rng.f32_log_uniform(-6, 6)).collect();
    let b: Vec<f32> = (0..n).map(|_| rng.f32_log_uniform(-6, 6)).collect();
    let q = engine.divide(&a, &b).unwrap();
    for i in 0..n {
        let nq = native.div_f32(a[i], b[i]);
        let ulp = (q[i].to_bits() as i64 - nq.to_bits() as i64).unsigned_abs();
        assert!(ulp <= 2, "lane {i}: pjrt {} vs native {nq}", q[i]);
    }
}
