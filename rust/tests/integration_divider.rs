//! Integration: all dividers cross-checked against each other and the
//! digit-recurrence gold reference across formats and workloads.

use tsdiv::analysis::{measure_accuracy_f32, Workload};
use tsdiv::divider::{
    all_dividers, goldschmidt::GoldschmidtDivider, longdiv::LongDivider, newton::NewtonDivider,
    Divider, TaylorDivider,
};
use tsdiv::fp::{ulp_diff, Rounding, BF16, F16, F32};
use tsdiv::harness::gen_special_batch;
use tsdiv::util::rng::Rng;

#[test]
fn all_dividers_within_1ulp_of_gold_on_log_uniform() {
    for mut d in all_dividers() {
        let name = d.name();
        if name.starts_with("taylor") && name.contains("ilm") {
            continue; // approximate backend measured separately below
        }
        let r = measure_accuracy_f32(d.as_mut(), Workload::LogUniform, 5_000, 42);
        assert!(r.max_ulp <= 1, "{name}: max {} ulp", r.max_ulp);
        assert!(r.exact_rate > 0.99, "{name}: exact rate {}", r.exact_rate);
    }
}

#[test]
fn ilm_divider_accuracy_by_iteration_budget() {
    // The paper's programmability claim: accuracy is a monotone function
    // of the ILM correction budget.
    let mut last_max_rel = f64::INFINITY;
    for iters in [2u32, 4, 8, 16, 32] {
        let mut d = TaylorDivider::paper_ilm(iters);
        let r = measure_accuracy_f32(&mut d, Workload::SignificandOnly, 3_000, 7);
        assert!(
            r.max_rel <= last_max_rel * 1.5 + 1e-12,
            "iters={iters}: {} vs prev {}",
            r.max_rel,
            last_max_rel
        );
        last_max_rel = r.max_rel;
    }
    assert!(last_max_rel < 1e-6, "32 corrections should be ≈ exact");
}

#[test]
fn dividers_consistent_across_formats() {
    let mut taylor = TaylorDivider::paper_exact();
    let mut gold = LongDivider::new();
    // f16 / bf16 quotients via the same datapath.
    for (a16, b16) in [(0x3C00u64, 0x4000u64), (0x4500, 0x3E00), (0x7BFF, 0x3C00)] {
        let t = taylor.div_bits(a16, b16, F16, Rounding::NearestEven);
        let g = gold.div_bits(a16, b16, F16, Rounding::NearestEven);
        let diff = (t as i64 - g as i64).unsigned_abs();
        assert!(diff <= 1, "f16 {a16:#x}/{b16:#x}: {t:#x} vs {g:#x}");
    }
    for (a, b) in [(0x3F80u64, 0x4000u64), (0x4049, 0x3FC0)] {
        let t = taylor.div_bits(a, b, BF16, Rounding::NearestEven);
        let g = gold.div_bits(a, b, BF16, Rounding::NearestEven);
        assert!((t as i64 - g as i64).unsigned_abs() <= 1, "bf16 {a:#x}/{b:#x}");
    }
}

#[test]
fn rounding_mode_bracketing_all_dividers() {
    // For every divider: RDN ≤ RNE ≤ RUP results (monotone modes).
    let mut rng = Rng::new(5);
    for _ in 0..200 {
        let a = rng.f32_log_uniform(-6, 6);
        let b = rng.f32_log_uniform(-6, 6);
        for mut d in [
            Box::new(TaylorDivider::paper_exact()) as Box<dyn Divider>,
            Box::new(NewtonDivider::paper_default()),
            Box::new(GoldschmidtDivider::paper_default()),
            Box::new(LongDivider::new()),
        ] {
            let mut q = |rm| {
                f32::from_bits(
                    d.div_bits(a.to_bits() as u64, b.to_bits() as u64, F32, rm) as u32
                )
            };
            let dn = q(Rounding::TowardNegative);
            let ne = q(Rounding::NearestEven);
            let up = q(Rounding::TowardPositive);
            assert!(dn <= ne && ne <= up, "{}: {a}/{b}: {dn} {ne} {up}", d.name());
        }
    }
}

#[test]
fn f64_path_agrees_with_hardware_to_2ulp() {
    let mut taylor = TaylorDivider::paper_exact();
    let mut newton = NewtonDivider::paper_default();
    let mut rng = Rng::new(9);
    for _ in 0..5_000 {
        let a = rng.f64_log_uniform(-200, 200);
        let b = rng.f64_log_uniform(-200, 200);
        let hw = a / b;
        for (q, name) in [(taylor.div_f64(a, b), "taylor"), (newton.div_f64(a, b), "newton")] {
            let ulp = tsdiv::fp::ulp_diff_f64(q, hw).unwrap();
            assert!(ulp <= 2, "{name} {a:e}/{b:e}: {ulp} ulp");
        }
    }
}

#[test]
fn adversarial_segment_edge_operands() {
    // Operands whose significands sit exactly on Table-I segment edges.
    let mut taylor = TaylorDivider::paper_exact();
    let mut gold = LongDivider::new();
    let bounds = tsdiv::pla::derive_segments(5, 53).expect("Table-I derivation");
    for &edge in &bounds {
        for delta in [-2i64, -1, 0, 1, 2] {
            let base = (edge.min(1.9999999) as f32).to_bits() as i64;
            let b = f32::from_bits((base + delta).clamp(0x3F80_0000, 0x3FFF_FFFF) as u32);
            for a in [1.0f32, 1.5, 1.9999999] {
                let t = taylor.div_f32(a, b);
                let g = gold.div_f32(a, b);
                let ulp = (t.to_bits() as i64 - g.to_bits() as i64).unsigned_abs();
                assert!(ulp <= 1, "{a}/{b} (edge {edge}): {ulp} ulp");
            }
        }
    }
}

#[test]
fn batch_path_survives_special_heavy_workload() {
    // The harness's special-value batch (NaN/±Inf/±0/subnormal lanes
    // mixed with random bit patterns) through the batched datapath,
    // checked lane-by-lane against the exactly-rounded gold reference.
    let batch = gen_special_batch(512, 9);
    let (a, b) = batch.bits_f32();
    let mut taylor = TaylorDivider::paper_exact();
    let mut out = vec![0u64; a.len()];
    taylor.div_bits_batch(&a, &b, F32, Rounding::NearestEven, &mut out);
    let mut gold = LongDivider::new();
    for i in 0..a.len() {
        let g = gold.div_bits(a[i], b[i], F32, Rounding::NearestEven);
        match ulp_diff(out[i], g, F32) {
            Some(u) => assert!(u <= 1, "lane {i}: {u} ulp vs gold"),
            None => {
                // NaN result: both paths must agree it is NaN.
                assert!(
                    f32::from_bits(out[i] as u32).is_nan()
                        && f32::from_bits(g as u32).is_nan(),
                    "lane {i}: NaN mismatch"
                );
            }
        }
    }
}

#[test]
fn latency_model_sanity_taylor_vs_longdiv() {
    // Cycle-model claim from the benches, kept honest in CI: the Fig-7
    // datapath needs fewer cycles than digit recurrence at f64 precision.
    let taylor = tsdiv::hw::divider_timing(60, 5, 2, false);
    let longdiv = tsdiv::hw::longdiv_timing(52);
    assert!(taylor.latency_cycles < longdiv.latency_cycles);
}
