//! Integration: the division service end to end — typed multi-format
//! requests, native and PJRT backends, fault injection, backpressure
//! under load.

use std::time::Duration;

use tsdiv::coordinator::{
    Backend, BackendChoice, DivRequest, DivisionService, GoldschmidtBackend, KernelBackend,
    ServiceConfig, SubmitError,
};
use tsdiv::divider::{longdiv::LongDivider, Divider};
use tsdiv::fp::{unpack, Class, Rounding, ALL_FORMATS};
use tsdiv::harness::{gen_bits_batch, special_patterns};
use tsdiv::kernel::KernelConfig;
use tsdiv::runtime::artifacts_available;
use tsdiv::util::rng::Rng;

fn cfg(workers: usize, max_batch: usize) -> ServiceConfig {
    ServiceConfig {
        workers,
        max_batch,
        max_wait: Duration::from_millis(2),
        queue_capacity: 1024,
        ..ServiceConfig::default()
    }
}

#[test]
fn native_service_under_concurrent_load() {
    let svc = DivisionService::start(
        cfg(4, 512),
        BackendChoice::Native {
            order: 5,
            ilm_iterations: None,
        },
    )
    .unwrap();
    let svc = std::sync::Arc::new(svc);
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let svc = std::sync::Arc::clone(&svc);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t);
            for _ in 0..50 {
                let n = (rng.below(63) + 1) as usize;
                let a: Vec<f32> = (0..n).map(|_| rng.f32_log_uniform(-8, 8)).collect();
                let b: Vec<f32> = (0..n).map(|_| rng.f32_log_uniform(-8, 8)).collect();
                let out = loop {
                    match svc.submit_request(DivRequest::from_f32(&a, &b)) {
                        Ok(ticket) => break ticket.wait().unwrap().to_f32().unwrap(),
                        Err(SubmitError::Busy) => std::thread::yield_now(),
                        Err(e) => panic!("{e}"),
                    }
                };
                for i in 0..n {
                    let want = a[i] / b[i];
                    assert!(
                        (out[i] - want).abs() <= want.abs() * 1e-6,
                        "lane {i}: {} vs {want}",
                        out[i]
                    );
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = svc.metrics();
    assert_eq!(m.requests, 8 * 50);
    assert!(m.failures == 0);
    assert!(m.latency_count == 8 * 50);
    assert!(m.mean_batch_lanes() > 1.0, "no coalescing happened");
    // Pure-f32 traffic: the dispatched cost gauge is exactly the lane
    // count at the reference weight.
    assert_eq!(
        m.cost_units,
        m.lanes * tsdiv::coordinator::REF_LANE_COST as u64,
        "f32 cost accounting"
    );
}

/// Every format rides the same service and the same `div_bits_batch`
/// lanes; the Native backend must stay within the datapath's ulp band
/// of the exactly-rounded gold reference in all of them, and specials
/// must agree in class.
#[test]
fn native_backend_serves_mixed_formats_within_ulp_band() {
    let svc = DivisionService::start(
        cfg(2, 128),
        BackendChoice::Native {
            order: 5,
            ilm_iterations: None,
        },
    )
    .unwrap();
    let mut gold = LongDivider::new();
    for (fi, fmt) in ALL_FORMATS.into_iter().enumerate() {
        for rm in Rounding::ALL {
            let (mut a, mut b) = gen_bits_batch(fmt, 96, 8, (fi as u64) << 3 | 1);
            // Sprinkle specials on top of the finite lanes.
            for (i, &s) in special_patterns(fmt).iter().enumerate() {
                a[i * 2] = s;
                b[i * 2 + 1] = s;
            }
            let resp = svc
                .divide_request_blocking(DivRequest::new(fmt, rm, a.clone(), b.clone()))
                .unwrap();
            assert_eq!(resp.fmt, fmt);
            assert_eq!(resp.rm, rm);
            for i in 0..a.len() {
                let want = gold.div_bits(a[i], b[i], fmt, rm);
                let got = resp.bits[i];
                match tsdiv::fp::ulp_diff(got, want, fmt) {
                    // 53-bit reciprocal precision: exact for the ≤24-bit
                    // significands, ≤2 ulp at f64's precision edge.
                    Some(u) => assert!(
                        u <= 2,
                        "{}/{rm:?} lane {i}: {got:#x} vs {want:#x} ({u} ulp)",
                        fmt.name()
                    ),
                    None => assert!(
                        unpack(got, fmt).class == Class::NaN
                            && unpack(want, fmt).class == Class::NaN,
                        "{}/{rm:?} lane {i}: NaN mismatch",
                        fmt.name()
                    ),
                }
            }
        }
    }
    assert_eq!(svc.metrics().failures, 0);
    svc.shutdown();
}

#[test]
fn pjrt_backend_service_roundtrip() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let svc = DivisionService::start(cfg(1, 1024), BackendChoice::Pjrt).unwrap();
    let a: Vec<f32> = (1..=100).map(|i| i as f32).collect();
    let b: Vec<f32> = (1..=100).map(|i| ((i % 5) + 1) as f32).collect();
    let out = svc
        .divide_request_blocking(DivRequest::from_f32(&a, &b))
        .unwrap()
        .to_f32()
        .unwrap();
    for i in 0..100 {
        let want = a[i] / b[i];
        let ulp = (out[i].to_bits() as i64 - want.to_bits() as i64).unsigned_abs();
        assert!(ulp <= 1, "lane {i}: {} vs {want}", out[i]);
    }
    // The PJRT artifact only serves f32/nearest: other keys must fail
    // the batch cleanly (backend error, not a wedged service).
    let err = svc
        .divide_request_blocking(DivRequest::from_f64(&[1.0], &[3.0]))
        .unwrap_err();
    assert!(err.contains("f32"), "{err}");
    assert!(svc.metrics().failures > 0);
    svc.shutdown();
}

#[test]
fn worker_survives_nan_heavy_batches() {
    // Specials must flow through without faulting workers.
    let svc = DivisionService::start(
        cfg(2, 128),
        BackendChoice::Native {
            order: 5,
            ilm_iterations: None,
        },
    )
    .unwrap();
    let a = vec![f32::NAN, 1.0, 0.0, f32::INFINITY, -1.0, 5.5];
    let b = vec![1.0, 0.0, 0.0, f32::INFINITY, f32::NAN, -0.0];
    let out = svc
        .divide_request_blocking(DivRequest::from_f32(&a, &b))
        .unwrap()
        .to_f32()
        .unwrap();
    for i in 0..a.len() {
        let want = a[i] / b[i];
        if want.is_nan() {
            assert!(out[i].is_nan(), "lane {i}");
        } else {
            assert_eq!(out[i].to_bits(), want.to_bits(), "lane {i}");
        }
    }
    // Service still healthy afterwards.
    assert_eq!(
        svc.divide_request_blocking(DivRequest::from_f32(&[8.0], &[2.0]))
            .unwrap()
            .to_f32()
            .unwrap(),
        vec![4.0]
    );
    assert_eq!(svc.metrics().failures, 0);
    svc.shutdown();
}

#[test]
fn ilm_backend_service_accuracy_band() {
    let svc = DivisionService::start(
        cfg(2, 256),
        BackendChoice::Native {
            order: 5,
            ilm_iterations: Some(8),
        },
    )
    .unwrap();
    let mut rng = Rng::new(12);
    let a: Vec<f32> = (0..500).map(|_| rng.f32_log_uniform(-8, 8)).collect();
    let b: Vec<f32> = (0..500).map(|_| rng.f32_log_uniform(-8, 8)).collect();
    let out = svc
        .divide_request_blocking(DivRequest::from_f32(&a, &b))
        .unwrap()
        .to_f32()
        .unwrap();
    for i in 0..a.len() {
        let want = a[i] / b[i];
        let rel = ((out[i] - want) / want).abs();
        assert!(rel < 1e-5, "lane {i}: rel err {rel}");
    }
    svc.shutdown();
}

/// Sharding must be a pure routing decision: the same mixed
/// format/rounding traffic through shards=1 and shards=4 produces
/// bit-identical response sets (the datapath is deterministic, so any
/// divergence is a routing or coalescing bug).
#[test]
fn sharded_service_equivalent_to_single_shard() {
    let run = |shards: usize| -> Vec<Vec<u64>> {
        let svc = DivisionService::start(
            ServiceConfig {
                workers: 4,
                shards: Some(shards),
                max_batch: 128,
                max_wait: Duration::from_millis(1),
                queue_capacity: 1024,
                ..ServiceConfig::default()
            },
            // Pinned Kernel, not the Native default: this test's whole
            // claim is that shard count never changes bits, so the
            // backend must be identical across both runs even when CI
            // exports TSDIV_ROUTER=auto (which upgrades only the Native
            // default, and whose per-batch picks are timing-dependent).
            BackendChoice::Kernel {
                order: 5,
                kernel: KernelConfig::default(),
            },
        )
        .unwrap();
        let mut tickets = Vec::new();
        for (fi, fmt) in ALL_FORMATS.into_iter().enumerate() {
            for (ri, rm) in Rounding::ALL.into_iter().enumerate() {
                for rep in 0..4u64 {
                    let seed = ((fi as u64) << 6) | ((ri as u64) << 3) | rep;
                    let (a, b) = gen_bits_batch(fmt, 33, 8, seed);
                    let t = loop {
                        match svc.submit_request(DivRequest::new(fmt, rm, a.clone(), b.clone())) {
                            Ok(t) => break t,
                            Err(SubmitError::Busy) => std::thread::yield_now(),
                            Err(e) => panic!("{e}"),
                        }
                    };
                    tickets.push(t);
                }
            }
        }
        let out: Vec<Vec<u64>> = tickets
            .into_iter()
            .map(|t| t.wait().unwrap().bits)
            .collect();
        assert_eq!(svc.metrics().failures, 0);
        svc.shutdown();
        out
    };
    assert_eq!(
        run(1),
        run(4),
        "shards=4 must be bit-identical to shards=1"
    );
}

/// The router's identity contract (shards-style): `Auto` may hand any
/// batch to either datapath, but the response content must be
/// **bit-identical to one of the fixed backends it routes between** —
/// routing decides *who* computes, never *what* is computed. Every
/// request here is small enough (33 lanes < max_batch) to travel as one
/// whole batch, so each response is exactly one datapath's output.
#[test]
fn auto_router_responses_bit_identical_to_a_fixed_backend() {
    let svc = DivisionService::start(
        ServiceConfig {
            workers: 4,
            max_batch: 128,
            max_wait: Duration::from_millis(1),
            queue_capacity: 1024,
            ..ServiceConfig::default()
        },
        BackendChoice::Auto,
    )
    .unwrap();
    // The two fixed datapaths `Auto` routes between, at the router's
    // own configurations (see `RoutedBackend::new`).
    let mut kern = KernelBackend::new(5, KernelConfig::default()).unwrap();
    let mut gs = GoldschmidtBackend::new(3, KernelConfig::default()).unwrap();
    let mut checked = 0usize;
    for (fi, fmt) in ALL_FORMATS.into_iter().enumerate() {
        for (ri, rm) in Rounding::ALL.into_iter().enumerate() {
            for rep in 0..3u64 {
                let seed = 0xA0 | ((fi as u64) << 6) | ((ri as u64) << 3) | rep;
                let (a, b) = gen_bits_batch(fmt, 33, 8, seed);
                let resp = svc
                    .divide_request_blocking(DivRequest::new(fmt, rm, a.clone(), b.clone()))
                    .unwrap();
                let qk = kern.divide(&a, &b, fmt, rm).unwrap();
                let qg = gs.divide(&a, &b, fmt, rm).unwrap();
                assert!(
                    resp.bits == qk || resp.bits == qg,
                    "{}/{rm:?} rep {rep}: routed response matches neither \
                     the Taylor kernel nor the Goldschmidt datapath",
                    fmt.name()
                );
                checked += 1;
            }
        }
    }
    assert_eq!(checked, 48);
    let m = svc.metrics();
    // Every batch was dispatched through the router, and the counters
    // saw all of them.
    assert_eq!(
        m.router_kernel_batches + m.router_goldschmidt_batches,
        m.batches,
        "router dispatch counters must cover every batch"
    );
    assert_eq!(m.failures, 0);
    svc.shutdown();
}

/// Many submitter threads race a mid-flight `close()`: every ticket
/// must resolve exactly once — a correct quotient or an explicit error,
/// never a hang — at shards=1 and shards=4 alike.
#[test]
fn shutdown_mid_flight_resolves_every_ticket_exactly_once() {
    for shards in [1usize, 4] {
        let svc = DivisionService::start(
            ServiceConfig {
                workers: 4,
                shards: Some(shards),
                max_batch: 256,
                max_wait: Duration::from_millis(1),
                queue_capacity: 1024,
                ..ServiceConfig::default()
            },
            BackendChoice::Native {
                order: 5,
                ilm_iterations: None,
            },
        )
        .unwrap();
        let tickets = std::sync::Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for tid in 0..8u32 {
                let svc = &svc;
                let tickets = &tickets;
                scope.spawn(move || {
                    for i in 0..200u32 {
                        let x = (tid * 1000 + i) as f32;
                        // x / 4.0 is exact in f32: a resolved ticket is
                        // checkable without a gold model.
                        match svc.submit_request(DivRequest::from_f32(&[x; 4], &[4.0; 4])) {
                            Ok(t) => tickets.lock().unwrap().push((x, t)),
                            Err(SubmitError::Busy) => std::thread::yield_now(),
                            Err(SubmitError::Closed) => break,
                            Err(e) => panic!("{e}"),
                        }
                    }
                });
            }
            // Pull the rug while submitters are mid-loop.
            std::thread::sleep(Duration::from_millis(2));
            svc.close();
        });
        let tickets = tickets.into_inner().unwrap();
        assert!(!tickets.is_empty(), "no ticket was ever accepted");
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        for (x, t) in tickets {
            // try_wait-poll instead of wait(): a hang here must fail the
            // test via the deadline, not wedge the suite.
            let resolved = loop {
                if let Some(r) = t.try_wait() {
                    break r;
                }
                assert!(
                    std::time::Instant::now() < deadline,
                    "ticket for {x} never resolved (shards={shards})"
                );
                std::thread::sleep(Duration::from_micros(50));
            };
            // An accepted ticket either completes correctly or reports
            // an explicit failure — both are "resolved exactly once".
            if let Ok(resp) = resolved {
                assert_eq!(resp.to_f32().unwrap(), vec![x / 4.0; 4], "shards={shards}");
            }
        }
        svc.shutdown();
    }
}

/// Single-key traffic lands on one shard by key affinity, so with 4
/// shards the other 3 home workers can only help by stealing — and
/// every stolen batch must still deliver each response to the waiter
/// that submitted it.
#[test]
fn stealing_keeps_responses_wired_to_their_tickets() {
    let svc = DivisionService::start(
        ServiceConfig {
            workers: 4,
            shards: Some(4),
            // Small budget so a burst of 64-lane requests emits many
            // ready batches on the hot shard's deque (64 × 3 < 256 × 3:
            // below the oversize threshold, so the spread tiebreak never
            // kicks in and the key stays on one shard).
            max_batch: 256,
            max_wait: Duration::from_millis(1),
            queue_capacity: 4096,
            ..ServiceConfig::default()
        },
        BackendChoice::Native {
            order: 5,
            ilm_iterations: None,
        },
    )
    .unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    let mut round = 0u64;
    loop {
        round += 1;
        let tickets: Vec<_> = (0..64u64)
            .map(|i| {
                let x = (round * 64 + i) as f32;
                let t = loop {
                    match svc.submit_request(DivRequest::from_f32(&[x; 64], &[2.0; 64])) {
                        Ok(t) => break t,
                        Err(SubmitError::Busy) => std::thread::yield_now(),
                        Err(e) => panic!("{e}"),
                    }
                };
                (x, t)
            })
            .collect();
        for (x, t) in tickets {
            assert_eq!(
                t.wait().unwrap().to_f32().unwrap(),
                vec![x / 2.0; 64],
                "round {round}: a stolen batch cross-wired its responses"
            );
        }
        // Steal counters flush when a worker parks; after each drained
        // round the pool goes idle, so flushed totals are visible here.
        if svc.metrics().steals > 0 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline && round < 500,
            "no steal ever observed: metrics = {:?}",
            svc.metrics()
        );
        std::thread::sleep(Duration::from_micros(200));
    }
    assert_eq!(svc.metrics().failures, 0);
    svc.shutdown();
}

#[test]
fn throughput_scales_with_workers() {
    // Not a strict benchmark — just require that 4 workers are no slower
    // than 1 on a saturated load (catching accidental serialization).
    let run = |workers: usize| -> f64 {
        let svc = DivisionService::start(
            cfg(workers, 4096),
            BackendChoice::Native {
                order: 5,
                ilm_iterations: None,
            },
        )
        .unwrap();
        let a = vec![3.0f32; 4096];
        let b = vec![7.0f32; 4096];
        let t0 = std::time::Instant::now();
        let tickets: Vec<_> = (0..32)
            .map(|_| loop {
                match svc.submit_request(DivRequest::from_f32(&a, &b)) {
                    Ok(t) => break t,
                    Err(SubmitError::Busy) => std::thread::yield_now(),
                    Err(e) => panic!("{e}"),
                }
            })
            .collect();
        for t in tickets {
            t.wait().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        svc.shutdown();
        32.0 * 4096.0 / dt
    };
    let t1 = run(1);
    let t4 = run(4);
    assert!(
        t4 > t1 * 0.8,
        "4 workers ({t4:.0}/s) slower than 1 ({t1:.0}/s)"
    );
}
