//! Cross-module property tests (the in-tree `util::check` framework):
//! system-level invariants spanning several modules at once.

use tsdiv::check_that;
use tsdiv::divider::{longdiv::LongDivider, Divider, TaylorDivider};
use tsdiv::fp::{next_down, next_up, round_pack, unpack, Class, F32, F64, Rounding};
use tsdiv::ilm::{ilm_mul, ilm_mul_exact};
use tsdiv::pla::{derive_segments, m_max, SegmentTable};
use tsdiv::powering::{ExactMul, IlmBackend, PoweringUnit};
use tsdiv::squaring::ilm_square;
use tsdiv::taylor::{reciprocal_fixed, TaylorConfig};
use tsdiv::util::check::{forall, Config};

#[test]
fn prop_ilm_equals_squaring_on_equal_operands() {
    forall(
        Config::named("ILM(n,n) == square(n) at any budget").cases(500),
        |d| {
            let n = d.range_u64(1, u32::MAX as u64);
            let iters = d.range_u64(0, 8) as u32;
            check_that!(ilm_mul(n, n, iters).product == ilm_square(n, iters).square);
            Ok(())
        },
    );
}

#[test]
fn prop_ilm_exact_matches_widening_multiply() {
    forall(Config::named("ILM full budget == u128 product").cases(500), |d| {
        let a = d.range_u64(0, u32::MAX as u64);
        let b = d.range_u64(0, u32::MAX as u64);
        check_that!(ilm_mul_exact(a, b) == a as u128 * b as u128);
        Ok(())
    });
}

#[test]
fn prop_powering_unit_powers_match_exact_powi() {
    forall(Config::named("powering unit == powi (exact backend)").cases(100), |d| {
        const F: u32 = 40;
        let xf = d.f64_range(0.05, 0.95);
        let x = (xf * (1u64 << F) as f64) as u64;
        let p = d.range_u64(2, 12) as u32;
        let mut be = ExactMul::default();
        let r = PoweringUnit::new(&mut be, F).compute_powers(x, p);
        for (i, &got) in r.powers.iter().enumerate() {
            let want = (x as f64 / (1u64 << F) as f64).powi(i as i32 + 1);
            let err = (got as f64 / (1u64 << F) as f64 - want).abs();
            // ≤ k truncations of 1 ulp each.
            check_that!(
                err <= (i as f64 + 1.0) / (1u64 << F) as f64,
                "x^{}: err {err}",
                i + 1
            );
        }
        Ok(())
    });
}

#[test]
fn prop_seed_error_within_eq17_m_max() {
    let bounds = derive_segments(5, 53).unwrap();
    let table = SegmentTable::build(&bounds, 60);
    forall(Config::named("PLA seed m ≤ m_max(segment)").cases(400), |d| {
        let x = d.f64_range(1.0, 1.999_999_9);
        let i = tsdiv::pla::segment_index(&bounds, x);
        let y0 = table.seed_f64(x);
        let m = 1.0 - x * y0;
        let tol = 16.0 / (1u64 << 60) as f64 * (1u64 << 8) as f64; // fixed-point slack
        check_that!(
            m <= m_max(bounds[i], bounds[i + 1]) + tol,
            "x={x}: m={m:e}"
        );
        Ok(())
    });
}

#[test]
fn prop_taylor_recip_independent_of_backend_at_full_budget() {
    let cfg = TaylorConfig::paper_default(60);
    forall(Config::named("ILM(64) backend == exact backend").cases(150), |d| {
        let x = d.range_u64(1u64 << 60, (1u64 << 61) - 1);
        let mut exact = ExactMul::default();
        let mut ilm = IlmBackend::new(64);
        let a = reciprocal_fixed(&cfg, &mut exact, x).recip;
        let b = reciprocal_fixed(&cfg, &mut ilm, x).recip;
        check_that!(a == b, "x={x}: {a} vs {b}");
        Ok(())
    });
}

#[test]
fn prop_divider_vs_gold_all_rounding_modes() {
    forall(Config::named("taylor ≤1 ulp of longdiv, any mode").cases(400), |d| {
        let a = d.f32_finite();
        let b = d.f32_finite();
        let rm = *[
            Rounding::NearestEven,
            Rounding::TowardZero,
            Rounding::TowardPositive,
            Rounding::TowardNegative,
        ]
        .get(d.choose_idx(4))
        .unwrap();
        let mut taylor = TaylorDivider::paper_exact();
        let mut gold = LongDivider::new();
        let t = taylor.div_bits(a.to_bits() as u64, b.to_bits() as u64, F32, rm);
        let g = gold.div_bits(a.to_bits() as u64, b.to_bits() as u64, F32, rm);
        match tsdiv::fp::ulp_diff(t, g, F32) {
            Some(u) => check_that!(u <= 1, "{a:?}/{b:?} {rm:?}: {u} ulp"),
            None => {
                check_that!(
                    unpack(t, F32).class == Class::NaN && unpack(g, F32).class == Class::NaN,
                    "NaN mismatch for {a:?}/{b:?}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_round_pack_monotone_in_significand() {
    forall(Config::named("round_pack monotone").cases(500), |d| {
        let q = 50u32;
        let sig = d.range_u64(1 << q, (1 << (q + 1)) - 2) as u128;
        let exp = d.range_i64(-40, 40) as i32;
        let (lo, _) = round_pack(false, exp, sig, q, false, F32, Rounding::NearestEven);
        let (hi, _) = round_pack(false, exp, sig + 1, q, false, F32, Rounding::NearestEven);
        check_that!(
            f32::from_bits(lo as u32) <= f32::from_bits(hi as u32),
            "sig {sig}: {lo:#x} > {hi:#x}"
        );
        Ok(())
    });
}

#[test]
fn prop_next_up_down_bracket_round_pack() {
    forall(Config::named("rounded value within one step of truth").cases(400), |d| {
        // Keep xf in a range where (xf · 2^100) as u128 retains ≥ 60
        // significant bits, so the fixture itself is not the error source.
        let xf = d.f64_range(1e-3, 1e3);
        let bits = round_pack(
            false,
            0,
            (xf * 2f64.powi(100)) as u128,
            100,
            false,
            F32,
            Rounding::NearestEven,
        )
        .0;
        let v = f32::from_bits(bits as u32) as f64;
        let up = f32::from_bits(next_up(bits, F32) as u32) as f64;
        let down = f32::from_bits(next_down(bits, F32) as u32) as f64;
        check_that!(down <= xf && xf <= up, "x={xf}: [{down}, {v}, {up}]");
        Ok(())
    });
}

#[test]
fn prop_div_bits_batch_bit_identical_to_scalar_f32_and_f64() {
    // NaN, ±Inf, ±0, smallest/largest subnormal, 1.0, largest finite.
    // Deliberately independent of `rng::F32_SPECIALS`: this is a test
    // fixture pinning exact bit patterns (incl. ones the runtime menu
    // lacks), so runtime-menu edits can't silently narrow coverage.
    const SPECIALS_F32: [u64; 9] = [
        0x7FC0_0000,
        0x7F80_0000,
        0xFF80_0000,
        0x0000_0000,
        0x8000_0000,
        0x0000_0001,
        0x007F_FFFF,
        0x3F80_0000,
        0x7F7F_FFFF,
    ];
    const SPECIALS_F64: [u64; 9] = [
        0x7FF8_0000_0000_0000,
        0x7FF0_0000_0000_0000,
        0xFFF0_0000_0000_0000,
        0x0000_0000_0000_0000,
        0x8000_0000_0000_0000,
        0x0000_0000_0000_0001,
        0x000F_FFFF_FFFF_FFFF,
        0x3FF0_0000_0000_0000,
        0x7FEF_FFFF_FFFF_FFFF,
    ];
    forall(Config::named("div_bits_batch == scalar div_bits").cases(40), |d| {
        let n = d.range_u64(1, 80) as usize;
        let rm = *[
            Rounding::NearestEven,
            Rounding::TowardZero,
            Rounding::TowardPositive,
            Rounding::TowardNegative,
        ]
        .get(d.choose_idx(4))
        .unwrap();
        for fmt_is_f64 in [false, true] {
            let (fmt, specials): (tsdiv::fp::Format, &[u64]) = if fmt_is_f64 {
                (F64, &SPECIALS_F64)
            } else {
                (F32, &SPECIALS_F32)
            };
            let mut a: Vec<u64> = Vec::with_capacity(n);
            let mut b: Vec<u64> = Vec::with_capacity(n);
            for i in 0..n {
                let mut ab = if fmt_is_f64 { d.u64() } else { d.u32() as u64 };
                let mut bb = if fmt_is_f64 { d.u64() } else { d.u32() as u64 };
                match i % 5 {
                    0 => ab = specials[d.choose_idx(specials.len())],
                    1 => bb = specials[d.choose_idx(specials.len())],
                    2 => {
                        // Repeated divisor → exercises the batch path's
                        // N-way reciprocal cache.
                        if let Some(&prev) = b.last() {
                            bb = prev;
                        }
                    }
                    _ => {}
                }
                a.push(ab);
                b.push(bb);
            }
            for ilm in [None, Some(3u32)] {
                let mut div = match ilm {
                    None => TaylorDivider::paper_exact(),
                    Some(k) => TaylorDivider::paper_ilm(k),
                };
                let scalar: Vec<u64> = a
                    .iter()
                    .zip(&b)
                    .map(|(&x, &y)| div.div_bits(x, y, fmt, rm))
                    .collect();
                let mut batch = vec![0u64; n];
                div.div_bits_batch(&a, &b, fmt, rm, &mut batch);
                check_that!(
                    scalar == batch,
                    "batch != scalar (f64={fmt_is_f64}, ilm={ilm:?}, rm={rm:?}, n={n})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_kernel_backend_bit_identical_to_scalar_datapath_all_formats() {
    // The staged SoA kernel (BackendChoice::Kernel) must equal the
    // per-lane scalar datapath bit for bit on every format, every
    // rounding mode, specials and subnormals included, at any tile
    // width — including batch lengths not divisible by the tile.
    use tsdiv::coordinator::{Backend, KernelBackend, ScalarNativeBackend};
    use tsdiv::fp::ALL_FORMATS;
    use tsdiv::harness::special_patterns;
    use tsdiv::kernel::KernelConfig;
    forall(Config::named("kernel backend == scalar datapath").cases(30), |d| {
        let fmt = ALL_FORMATS[d.choose_idx(4)];
        let rm = Rounding::ALL[d.choose_idx(4)];
        let tile = [1usize, 3, 8, 13][d.choose_idx(4)];
        // Deliberately awkward length: rarely a tile multiple.
        let n = d.range_u64(1, 70) as usize;
        let specials = special_patterns(fmt);
        let mut a = Vec::with_capacity(n);
        let mut b = Vec::with_capacity(n);
        for i in 0..n {
            let mut ab = d.u64() & fmt.width_mask();
            let mut bb = d.u64() & fmt.width_mask();
            match i % 5 {
                0 => ab = specials[d.choose_idx(specials.len())],
                1 => bb = specials[d.choose_idx(specials.len())],
                2 => {
                    // Repeated divisor → exercises the kernel's
                    // per-tile reciprocal cache.
                    if let Some(&prev) = b.last() {
                        bb = prev;
                    }
                }
                _ => {}
            }
            a.push(ab);
            b.push(bb);
        }
        for ilm in [None, Some(3u32)] {
            let mut kern = KernelBackend::new(
                5,
                KernelConfig {
                    tile,
                    ilm_iterations: ilm,
                    ..KernelConfig::default()
                },
            )
            .unwrap();
            let mut scalar = ScalarNativeBackend::new(5, ilm).unwrap();
            let qk = kern.divide(&a, &b, fmt, rm).map_err(|e| e.to_string())?;
            let qs = scalar.divide(&a, &b, fmt, rm).map_err(|e| e.to_string())?;
            check_that!(
                qk == qs,
                "kernel != scalar ({}, {rm:?}, tile={tile}, ilm={ilm:?}, n={n})",
                fmt.name()
            );
        }
        Ok(())
    });
}

/// The lane-engine acceptance invariant: the forced-SIMD kernel equals
/// the forced-scalar kernel equals the per-lane scalar datapath, bit for
/// bit, for all formats × rounding modes × tile widths — including
/// batch lengths that are not tile multiples, special and subnormal
/// lanes, repeated divisors (reciprocal-cache hits) and both multiplier
/// backends. On hosts with a vector engine the `Forced` choice
/// exercises the widest one; elsewhere that half is skipped (scalar vs
/// scalar would be vacuous) but the kernel-vs-datapath half still runs.
/// A final sweep drives `kernel::divide_batch` with **every** detected
/// engine — on an AVX-512 host that pins scalar, AVX2 *and* AVX-512
/// (and on aarch64, NEON) against the same forced-scalar kernel
/// result, vectorized ILM priority encoder included.
#[test]
fn prop_forced_simd_kernel_bit_identical_to_forced_scalar_and_datapath() {
    use tsdiv::coordinator::{Backend, KernelBackend, ScalarNativeBackend};
    use tsdiv::fp::ALL_FORMATS;
    use tsdiv::harness::special_patterns;
    use tsdiv::kernel::{divide_batch, KernelConfig, KernelScratch};
    use tsdiv::powering::{ExactMul, IlmBackend};
    use tsdiv::simd::{engines_available, simd_available, SimdChoice};
    use tsdiv::taylor::TaylorConfig;
    forall(
        Config::named("forced-simd kernel == forced-scalar kernel == datapath").cases(30),
        |d| {
            let fmt = ALL_FORMATS[d.choose_idx(4)];
            let rm = Rounding::ALL[d.choose_idx(4)];
            let tile = [1usize, 3, 8, 13][d.choose_idx(4)];
            // Deliberately awkward length: rarely a tile multiple.
            let n = d.range_u64(1, 70) as usize;
            let specials = special_patterns(fmt);
            let mut a = Vec::with_capacity(n);
            let mut b = Vec::with_capacity(n);
            for i in 0..n {
                let mut ab = d.u64() & fmt.width_mask();
                let mut bb = d.u64() & fmt.width_mask();
                match i % 5 {
                    0 => ab = specials[d.choose_idx(specials.len())],
                    1 => bb = specials[d.choose_idx(specials.len())],
                    2 => {
                        // Repeated divisor → reciprocal-cache hits on
                        // both engines.
                        if let Some(&prev) = b.last() {
                            bb = prev;
                        }
                    }
                    _ => {}
                }
                a.push(ab);
                b.push(bb);
            }
            for ilm in [None, Some(3u32)] {
                let mut scalar_kern = KernelBackend::new(
                    5,
                    KernelConfig {
                        tile,
                        ilm_iterations: ilm,
                        simd: SimdChoice::Scalar,
                    },
                )
                .unwrap();
                let mut datapath = ScalarNativeBackend::new(5, ilm).unwrap();
                let qsk = scalar_kern.divide(&a, &b, fmt, rm).map_err(|e| e.to_string())?;
                let qd = datapath.divide(&a, &b, fmt, rm).map_err(|e| e.to_string())?;
                check_that!(
                    qsk == qd,
                    "forced-scalar kernel != datapath ({}, {rm:?}, tile={tile}, ilm={ilm:?})",
                    fmt.name()
                );
                if simd_available() {
                    let mut simd_kern = KernelBackend::new(
                        5,
                        KernelConfig {
                            tile,
                            ilm_iterations: ilm,
                            simd: SimdChoice::Forced,
                        },
                    )
                    .unwrap();
                    let qf = simd_kern.divide(&a, &b, fmt, rm).map_err(|e| e.to_string())?;
                    check_that!(
                        qf == qsk,
                        "forced-simd != forced-scalar ({}, {rm:?}, tile={tile}, ilm={ilm:?})",
                        fmt.name()
                    );
                }
                // Every *detected* engine — not just the widest one
                // `Forced` resolves to — must match the forced-scalar
                // kernel bit for bit. Driving `kernel::divide_batch`
                // directly pins the intermediate engines too (AVX2 on
                // an AVX-512 host) and runs the vectorized ILM
                // priority-encoder pass under every vector width.
                let cfg = TaylorConfig {
                    order: 5,
                    ..TaylorConfig::paper_default(60)
                };
                for eng in engines_available() {
                    let mut out = vec![0u64; n];
                    let mut scratch = KernelScratch::new();
                    match ilm {
                        None => {
                            let mut be = ExactMul::default();
                            divide_batch(
                                &cfg, &mut be, &mut scratch, tile, eng, &a, &b, fmt, rm, &mut out,
                            );
                        }
                        Some(iterations) => {
                            let mut be = IlmBackend::new(iterations);
                            divide_batch(
                                &cfg, &mut be, &mut scratch, tile, eng, &a, &b, fmt, rm, &mut out,
                            );
                        }
                    }
                    check_that!(
                        out == qsk,
                        "engine {} != forced-scalar kernel ({}, {rm:?}, tile={tile}, ilm={ilm:?})",
                        eng.name(),
                        fmt.name()
                    );
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kernel_backend_vs_gold_all_formats_and_roundings() {
    // Against the exactly-rounded longdiv gold reference: every special
    // lane (resolved by the shared prepare() path) is bit-identical;
    // finite lanes stay inside the Taylor unit's documented band (the
    // 2^-53 reciprocal leaves ≤ 1 ulp in the ≤ 24-bit formats and ≤ 2
    // ulp at f64's precision edge) — the same band the scalar datapath
    // is pinned to.
    use tsdiv::coordinator::{Backend, KernelBackend};
    use tsdiv::fp::{ulp_diff, ALL_FORMATS, F64};
    use tsdiv::harness::special_patterns;
    use tsdiv::kernel::KernelConfig;
    forall(Config::named("kernel backend vs gold (longdiv)").cases(30), |d| {
        let fmt = ALL_FORMATS[d.choose_idx(4)];
        let rm = Rounding::ALL[d.choose_idx(4)];
        let n = d.range_u64(1, 60) as usize;
        let specials = special_patterns(fmt);
        let mut a = Vec::with_capacity(n);
        let mut b = Vec::with_capacity(n);
        for i in 0..n {
            let mut ab = d.u64() & fmt.width_mask();
            let mut bb = d.u64() & fmt.width_mask();
            match i % 4 {
                0 => ab = specials[d.choose_idx(specials.len())],
                1 => bb = specials[d.choose_idx(specials.len())],
                _ => {}
            }
            a.push(ab);
            b.push(bb);
        }
        let mut kern = KernelBackend::new(5, KernelConfig::default()).unwrap();
        let mut gold = LongDivider::new();
        let qk = kern.divide(&a, &b, fmt, rm).map_err(|e| e.to_string())?;
        let band = if fmt == F64 { 2 } else { 1 };
        for i in 0..n {
            let g = gold.div_bits(a[i], b[i], fmt, rm);
            let special = matches!(
                tsdiv::divider::prepare(a[i], b[i], fmt),
                tsdiv::divider::Prepared::Done(_)
            );
            match ulp_diff(qk[i], g, fmt) {
                Some(u) if special => check_that!(
                    u == 0,
                    "special lane {i} not bit-identical to gold ({}/{rm:?})",
                    fmt.name()
                ),
                Some(u) => check_that!(
                    u <= band,
                    "lane {i}: {u} ulp from gold ({}/{rm:?})",
                    fmt.name()
                ),
                None => check_that!(
                    unpack(qk[i], fmt).class == Class::NaN
                        && unpack(g, fmt).class == Class::NaN,
                    "NaN mismatch at lane {i} ({}/{rm:?})",
                    fmt.name()
                ),
            }
        }
        Ok(())
    });
}

#[test]
fn prop_goldschmidt_backend_vs_kernel_and_gold_all_formats() {
    // Three-way differential over the two first-class datapaths and the
    // exactly-rounded reference, across formats × rounding modes ×
    // tile widths:
    //
    // * the batched Goldschmidt backend is **bit-identical per lane**
    //   to the scalar `GoldschmidtDivider` oracle (same iterate
    //   arithmetic, any tiling);
    // * specials (resolved by the shared prepare() path) are
    //   bit-identical to gold on BOTH datapaths;
    // * finite lanes stay inside each datapath's documented band vs
    //   gold (≤ 1 ulp in the ≤ 24-bit formats, ≤ 2 ulp at f64) — the
    //   router may hand a batch to either datapath, so both bands must
    //   hold on the same operands.
    use tsdiv::coordinator::{Backend, GoldschmidtBackend, KernelBackend};
    use tsdiv::divider::goldschmidt::GoldschmidtDivider;
    use tsdiv::fp::{ulp_diff, ALL_FORMATS};
    use tsdiv::harness::special_patterns;
    use tsdiv::kernel::KernelConfig;
    forall(
        Config::named("goldschmidt vs kernel vs gold (longdiv)").cases(24),
        |d| {
            let fmt = ALL_FORMATS[d.choose_idx(4)];
            let rm = Rounding::ALL[d.choose_idx(4)];
            let tile = [1usize, 3, 8, 13][d.choose_idx(4)];
            let n = d.range_u64(1, 60) as usize;
            let specials = special_patterns(fmt);
            let mut a = Vec::with_capacity(n);
            let mut b = Vec::with_capacity(n);
            for i in 0..n {
                let mut ab = d.u64() & fmt.width_mask();
                let mut bb = d.u64() & fmt.width_mask();
                match i % 4 {
                    0 => ab = specials[d.choose_idx(specials.len())],
                    1 => bb = specials[d.choose_idx(specials.len())],
                    _ => {}
                }
                a.push(ab);
                b.push(bb);
            }
            let cfg = KernelConfig {
                tile,
                ..KernelConfig::default()
            };
            let mut gs = GoldschmidtBackend::new(3, cfg).map_err(|e| e.to_string())?;
            let mut kern = KernelBackend::new(5, cfg).map_err(|e| e.to_string())?;
            let mut oracle = GoldschmidtDivider::paper_default();
            let mut gold = LongDivider::new();
            let qg = gs.divide(&a, &b, fmt, rm).map_err(|e| e.to_string())?;
            let qk = kern.divide(&a, &b, fmt, rm).map_err(|e| e.to_string())?;
            let band = if fmt == F64 { 2 } else { 1 };
            for i in 0..n {
                check_that!(
                    qg[i] == oracle.div_bits(a[i], b[i], fmt, rm),
                    "lane {i}: batched goldschmidt differs from the scalar oracle \
                     ({}/{rm:?}, tile {tile})",
                    fmt.name()
                );
                let g = gold.div_bits(a[i], b[i], fmt, rm);
                let special = matches!(
                    tsdiv::divider::prepare(a[i], b[i], fmt),
                    tsdiv::divider::Prepared::Done(_)
                );
                for (label, q) in [("goldschmidt", qg[i]), ("kernel", qk[i])] {
                    match ulp_diff(q, g, fmt) {
                        Some(u) if special => check_that!(
                            u == 0,
                            "{label} special lane {i} not bit-identical to gold ({}/{rm:?})",
                            fmt.name()
                        ),
                        Some(u) => check_that!(
                            u <= band,
                            "{label} lane {i}: {u} ulp from gold ({}/{rm:?})",
                            fmt.name()
                        ),
                        None => check_that!(
                            unpack(q, fmt).class == Class::NaN
                                && unpack(g, fmt).class == Class::NaN,
                            "{label} NaN mismatch at lane {i} ({}/{rm:?})",
                            fmt.name()
                        ),
                    }
                }
            }
            Ok(())
        },
    );
}

/// Random operand triple in the shape `op` expects: unary ops carry
/// only `a`; `ScaleByRecip` carries ragged rows (rarely tile
/// multiples) with one divisor each; `Div` carries matched `a`/`b`.
/// Specials and subnormals are mixed into every position.
fn gen_op_operands(
    d: &mut tsdiv::util::check::Draw,
    op: tsdiv::fp::Op,
    fmt: tsdiv::fp::Format,
) -> (Vec<u64>, Vec<u64>, Vec<u32>) {
    use tsdiv::fp::Op;
    use tsdiv::harness::special_patterns;
    let specials = special_patterns(fmt);
    let mut pick = |d: &mut tsdiv::util::check::Draw, special: bool| {
        if special {
            specials[d.choose_idx(specials.len())]
        } else {
            d.u64() & fmt.width_mask()
        }
    };
    match op {
        Op::ScaleByRecip => {
            let nrows = d.range_u64(1, 7) as usize;
            let mut rows = Vec::with_capacity(nrows);
            let mut b = Vec::with_capacity(nrows);
            let mut lanes = 0usize;
            for r in 0..nrows {
                let len = d.range_u64(1, 17) as u32;
                rows.push(len);
                lanes += len as usize;
                b.push(pick(d, r % 3 == 0));
            }
            let a = (0..lanes).map(|i| pick(d, i % 5 == 0)).collect();
            (a, b, rows)
        }
        Op::Div => {
            let n = d.range_u64(1, 60) as usize;
            let a = (0..n).map(|i| pick(d, i % 5 == 0)).collect();
            let b = (0..n).map(|i| pick(d, i % 5 == 1)).collect();
            (a, b, Vec::new())
        }
        Op::Recip | Op::Rsqrt => {
            let n = d.range_u64(1, 60) as usize;
            let a = (0..n).map(|i| pick(d, i % 4 == 0)).collect();
            (a, Vec::new(), Vec::new())
        }
    }
}

/// Per-op differential over both first-class datapaths and the
/// exactly-rounded longdiv references, across formats × rounding modes
/// × tile widths — the typed-op analogue of the Div three-way test
/// above:
///
/// * `Recip` is **bit-identical** to `Div(1.0, x)` on both datapaths;
/// * the Taylor kernel's `ScaleByRecip` is **bit-identical** to `Div`
///   against the row-expanded divisor vector (same final multiply,
///   reciprocal amortized by the divisor cache); the Goldschmidt tail
///   truncates the reciprocal before the broadcast multiply, so there
///   it is a band, not an identity;
/// * special lanes (NaN/∞/zero inputs; negative rsqrt operands) are
///   bit-identical to gold on both datapaths;
/// * finite lanes stay inside the documented band of the
///   exactly-rounded reference (≤ 1 ulp in the ≤ 24-bit formats, ≤ 2
///   ulp at f64).
#[test]
fn prop_per_op_kernel_and_goldschmidt_vs_gold_all_formats() {
    use tsdiv::coordinator::{Backend, GoldschmidtBackend, KernelBackend};
    use tsdiv::fp::{ulp_diff, Op, ALL_FORMATS};
    use tsdiv::kernel::KernelConfig;
    forall(Config::named("typed ops vs gold (longdiv)").cases(24), |d| {
        let fmt = ALL_FORMATS[d.choose_idx(4)];
        let rm = Rounding::ALL[d.choose_idx(4)];
        let tile = [1usize, 3, 8, 13][d.choose_idx(4)];
        let op = [Op::Recip, Op::Rsqrt, Op::ScaleByRecip][d.choose_idx(3)];
        let (a, b, rows) = gen_op_operands(d, op, fmt);
        let cfg = KernelConfig {
            tile,
            ..KernelConfig::default()
        };
        let mut kern = KernelBackend::new(5, cfg).map_err(|e| e.to_string())?;
        let mut gs = GoldschmidtBackend::new(3, cfg).map_err(|e| e.to_string())?;
        let mut gold = LongDivider::new();
        let qk = kern
            .compute(op, &a, &b, &rows, fmt, rm)
            .map_err(|e| e.to_string())?;
        let qg = gs
            .compute(op, &a, &b, &rows, fmt, rm)
            .map_err(|e| e.to_string())?;
        check_that!(qk.len() == a.len() && qg.len() == a.len());
        if op == Op::Recip {
            // Recip ≡ Div(1.0, x), bit for bit, on both datapaths.
            let ones = vec![fmt.one(); a.len()];
            let dk = kern.divide(&ones, &a, fmt, rm).map_err(|e| e.to_string())?;
            let dg = gs.divide(&ones, &a, fmt, rm).map_err(|e| e.to_string())?;
            check_that!(qk == dk, "kernel recip != div(1,x) ({}/{rm:?})", fmt.name());
            check_that!(
                qg == dg,
                "goldschmidt recip != div(1,x) ({}/{rm:?})",
                fmt.name()
            );
        }
        if op == Op::ScaleByRecip {
            // Taylor fused tail == Div on the row-expanded divisors.
            let mut expanded = Vec::with_capacity(a.len());
            for (&len, &div) in rows.iter().zip(&b) {
                expanded.resize(expanded.len() + len as usize, div);
            }
            let dk = kern
                .divide(&a, &expanded, fmt, rm)
                .map_err(|e| e.to_string())?;
            check_that!(
                qk == dk,
                "kernel scale-by-recip != div on expanded divisors ({}/{rm:?}, tile {tile})",
                fmt.name()
            );
        }
        let band = if fmt == F64 { 2 } else { 1 };
        let is_special_class =
            |bits: u64| matches!(unpack(bits, fmt).class, Class::NaN | Class::Inf | Class::Zero);
        let mut row = 0usize;
        let mut row_rem = rows.first().copied().unwrap_or(0);
        for i in 0..a.len() {
            let (g, special) = match op {
                Op::Recip => (gold.recip_bits(a[i], fmt, rm), is_special_class(a[i])),
                Op::Rsqrt => {
                    let u = unpack(a[i], fmt);
                    (
                        gold.rsqrt_bits(a[i], fmt, rm),
                        u.sign || is_special_class(a[i]),
                    )
                }
                Op::ScaleByRecip => {
                    while row_rem == 0 {
                        row += 1;
                        row_rem = rows[row];
                    }
                    row_rem -= 1;
                    (
                        gold.div_bits(a[i], b[row], fmt, rm),
                        is_special_class(a[i]) || is_special_class(b[row]),
                    )
                }
                Op::Div => unreachable!("Div is covered by the three-way test above"),
            };
            for (label, q) in [("kernel", qk[i]), ("goldschmidt", qg[i])] {
                match ulp_diff(q, g, fmt) {
                    Some(u) if special => check_that!(
                        u == 0,
                        "{label} {op:?} special lane {i} not bit-identical to gold ({}/{rm:?})",
                        fmt.name()
                    ),
                    Some(u) => check_that!(
                        u <= band,
                        "{label} {op:?} lane {i}: {u} ulp from gold ({}/{rm:?}, tile {tile})",
                        fmt.name()
                    ),
                    None => check_that!(
                        unpack(q, fmt).class == Class::NaN && unpack(g, fmt).class == Class::NaN,
                        "{label} {op:?} NaN mismatch at lane {i} ({}/{rm:?})",
                        fmt.name()
                    ),
                }
            }
        }
        Ok(())
    });
}

/// Nonzero `trunc_bits` through the served Goldschmidt backend: a
/// `t`-bit truncation on the paper's Q2.60 grid perturbs the
/// `k`-iteration chain by `(2k + 2)·2^(t−60)` relative, which stays
/// under one result ulp while `t ≤ 60 − frac_bits − log2(2k+2) − 1`
/// (module doc in `kernel/goldschmidt.rs`). Picking the largest such
/// `t` per format (8 for the ≤ 24-bit formats, 4 at f64), the
/// truncated backend rounds to within 1 ulp of the exact-width one
/// (and resolves specials identically) for every op, format and
/// rounding mode.
#[test]
fn prop_truncated_goldschmidt_within_one_ulp_of_exact_all_ops() {
    use tsdiv::coordinator::{Backend, GoldschmidtBackend};
    use tsdiv::fp::{ulp_diff, Op, ALL_FORMATS};
    use tsdiv::kernel::KernelConfig;
    forall(Config::named("trunc-bits goldschmidt vs exact").cases(24), |d| {
        let fmt = ALL_FORMATS[d.choose_idx(4)];
        let rm = Rounding::ALL[d.choose_idx(4)];
        let op = [Op::Div, Op::Recip, Op::Rsqrt, Op::ScaleByRecip][d.choose_idx(4)];
        let (a, b, rows) = gen_op_operands(d, op, fmt);
        let trunc_bits = if fmt.frac_bits > 23 { 4 } else { 8 };
        let mut tr = GoldschmidtBackend::with_trunc(3, trunc_bits, KernelConfig::default())
            .map_err(|e| e.to_string())?;
        let mut ex = GoldschmidtBackend::new(3, KernelConfig::default())
            .map_err(|e| e.to_string())?;
        let qt = tr
            .compute(op, &a, &b, &rows, fmt, rm)
            .map_err(|e| e.to_string())?;
        let qe = ex
            .compute(op, &a, &b, &rows, fmt, rm)
            .map_err(|e| e.to_string())?;
        for i in 0..qt.len() {
            match ulp_diff(qt[i], qe[i], fmt) {
                Some(u) => check_that!(
                    u <= 1,
                    "{op:?} lane {i}: {u} ulp between trunc={trunc_bits} and exact ({}/{rm:?})",
                    fmt.name()
                ),
                // NaN lanes resolve in the plan stage, before the
                // truncated iterate — identical bits.
                None => check_that!(qt[i] == qe[i], "{op:?} NaN lane {i} ({}/{rm:?})", fmt.name()),
            }
        }
        Ok(())
    });
}

/// Cost-weighted batch assembly (the adaptive batcher's tentpole
/// invariants), over random mixed-format push streams:
///
/// 1. **No starvation / conservation** — every pushed request appears in
///    exactly one emitted batch once the assembler drains, with per-key
///    arrival order preserved;
/// 2. **Budget bound** — an emitted batch never exceeds the cost budget
///    by more than its own final request's cost (so one stray oversize
///    request can stretch a batch, but accumulated traffic cannot);
/// 3. **Cost totals** — every batch's `cost` equals the sum of its
///    lanes weighted by its key's `lane_cost`, and the assembler's
///    pending gauges track exactly what was pushed minus what flushed.
#[test]
fn prop_cost_weighted_assembly_never_starves_and_bounds_cost() {
    use std::collections::HashMap;
    use tsdiv::coordinator::{Batch, BatchAssembler, BatchItem, BatchKey};
    use tsdiv::fp::ALL_FORMATS;
    forall(Config::named("cost-weighted batch assembly").cases(60), |d| {
        let max_lanes = d.range_u64(1, 48) as usize;
        let mut asm = BatchAssembler::new(max_lanes);
        let budget = asm.cost_budget();
        check_that!(budget == max_lanes * tsdiv::coordinator::REF_LANE_COST);
        let npush = d.range_u64(1, 120) as usize;
        let mut pushed: HashMap<u64, (BatchKey, usize)> = HashMap::new();
        let mut pushed_cost = 0usize;
        let mut pushed_lanes = 0usize;
        let mut flushed: Vec<Batch> = Vec::new();
        let mut flushed_cost = 0usize;
        let mut flushed_lanes = 0usize;
        for id in 0..npush as u64 {
            let key = BatchKey::new(ALL_FORMATS[d.choose_idx(4)], Rounding::ALL[d.choose_idx(4)]);
            let lanes = d.range_u64(1, 40) as usize;
            pushed.insert(id, (key, lanes));
            pushed_cost += lanes * key.lane_cost();
            pushed_lanes += lanes;
            let item = BatchItem {
                request_id: id,
                a: vec![id; lanes],
                b: vec![1; lanes],
                rows: vec![],
            };
            if let Some(b) = asm.push(key, item) {
                check_that!(b.key == key, "a push can only flush its own key's bucket");
                // Invariant 2: over-budget only by the final request.
                let last_cost =
                    b.items.last().map_or(0, |it| it.a.len() * b.key.lane_cost());
                check_that!(
                    b.cost <= budget || b.cost - last_cost < budget,
                    "batch cost {} exceeds budget {budget} by more than its last \
                     request ({last_cost})",
                    b.cost
                );
                flushed_cost += b.cost;
                flushed_lanes += b.lanes;
                flushed.push(b);
            }
            // Invariant 3: the pending gauges track push − flush exactly.
            check_that!(asm.pending_cost() == pushed_cost - flushed_cost);
            check_that!(asm.pending_lanes() == pushed_lanes - flushed_lanes);
        }
        for b in asm.take_all() {
            // Drained remainders were never pushed over the budget.
            check_that!(b.cost <= budget, "undrained bucket over budget");
            flushed.push(b);
        }
        check_that!(asm.pending_cost() == 0 && asm.pending_lanes() == 0);
        // Invariants 1 + 3 over the full stream.
        let mut seen: HashMap<u64, usize> = HashMap::new();
        let mut last_id_per_key: HashMap<String, u64> = HashMap::new();
        for b in &flushed {
            let mut lanes = 0usize;
            for it in &b.items {
                *seen.entry(it.request_id).or_insert(0) += 1;
                let (key, n) = pushed[&it.request_id];
                check_that!(key == b.key, "request routed into a foreign key's batch");
                check_that!(it.a.len() == n, "request lanes mutated in flight");
                lanes += n;
                // Per-key arrival order: ids grow monotonically across
                // this key's successive batches (flushed Vec preserves
                // emission order; within a batch, item order).
                let e = last_id_per_key.entry(b.key.to_string()).or_insert(0);
                check_that!(
                    *e <= it.request_id || *e == 0,
                    "key {} reordered: {} after {}",
                    b.key,
                    it.request_id,
                    e
                );
                *e = it.request_id;
            }
            check_that!(b.lanes == lanes, "batch lane count mismatch");
            check_that!(
                b.cost == lanes * b.key.lane_cost(),
                "batch cost {} != lanes {lanes} × lane_cost {}",
                b.cost,
                b.key.lane_cost()
            );
        }
        check_that!(seen.len() == npush, "a request starved (never emitted)");
        check_that!(
            seen.values().all(|&c| c == 1),
            "a request was emitted more than once"
        );
        // Invariant 3 (mixed-format totals): the cost that flowed
        // through equals the per-format lane_cost-weighted sum of the
        // original stream.
        let total: usize = flushed.iter().map(|b| b.cost).sum();
        check_that!(total == pushed_cost, "cost total {total} != pushed {pushed_cost}");
        Ok(())
    });
}

#[test]
fn prop_service_roundtrip_preserves_lane_order() {
    use tsdiv::coordinator::{BackendChoice, DivRequest, DivisionService, ServiceConfig};
    let svc = DivisionService::start(
        ServiceConfig {
            workers: 3,
            max_batch: 97, // deliberately odd to force splits
            max_wait: std::time::Duration::from_micros(200),
            queue_capacity: 256,
            ..ServiceConfig::default()
        },
        BackendChoice::Native {
            order: 5,
            ilm_iterations: None,
        },
    )
    .unwrap();
    forall(Config::named("service preserves order").cases(40), |d| {
        let n = d.range_u64(1, 300) as usize;
        let a: Vec<f32> = (0..n).map(|i| i as f32 + 1.0).collect();
        let b: Vec<f32> = (0..n).map(|_| d.f64_range(0.5, 4.0) as f32).collect();
        let out = svc
            .divide_request_blocking(DivRequest::from_f32(&a, &b))
            .map_err(|e| e.to_string())?
            .to_f32()
            .expect("binary32 response");
        check_that!(out.len() == n);
        for i in 0..n {
            let want = a[i] / b[i];
            check_that!(
                (out[i] - want).abs() <= want.abs() * 1e-6,
                "lane {i} out of order or wrong"
            );
        }
        Ok(())
    });
    svc.shutdown();
}

/// The tentpole invariant of the typed service: a mixed-format,
/// mixed-rounding request stream (specials included) served by the
/// exactly-rounded gold backend is **bit-identical** to running
/// `longdiv` per lane, and every response routes back to the ticket of
/// the request that produced it, with the request's format and rounding
/// echoed.
#[test]
fn prop_mixed_format_stream_bit_identical_to_longdiv_gold() {
    use tsdiv::coordinator::{BackendChoice, DivRequest, DivisionService, ServiceConfig};
    use tsdiv::fp::ALL_FORMATS;
    use tsdiv::harness::special_patterns;
    let svc = DivisionService::start(
        ServiceConfig {
            workers: 3,
            max_batch: 61, // odd budget → batches split mid-stream
            max_wait: std::time::Duration::from_micros(200),
            queue_capacity: 512,
            ..ServiceConfig::default()
        },
        BackendChoice::Gold,
    )
    .unwrap();
    forall(Config::named("mixed-format stream == longdiv per lane").cases(25), |d| {
        // A burst of interleaved requests across formats and modes.
        let nreq = d.range_u64(2, 12) as usize;
        let mut inflight = Vec::new();
        for _ in 0..nreq {
            let fmt = ALL_FORMATS[d.choose_idx(4)];
            let rm = Rounding::ALL[d.choose_idx(4)];
            let specials = special_patterns(fmt);
            let n = d.range_u64(1, 50) as usize;
            let mut a = Vec::with_capacity(n);
            let mut b = Vec::with_capacity(n);
            for i in 0..n {
                let mut ab = d.u64() & fmt.width_mask();
                let mut bb = d.u64() & fmt.width_mask();
                match i % 4 {
                    0 => ab = specials[d.choose_idx(specials.len())],
                    1 => bb = specials[d.choose_idx(specials.len())],
                    _ => {}
                }
                a.push(ab);
                b.push(bb);
            }
            let ticket = svc
                .submit_request(DivRequest::new(fmt, rm, a.clone(), b.clone()))
                .expect("queue sized for the burst");
            inflight.push((ticket, fmt, rm, a, b));
        }
        // Ticket ids must be distinct (response routing is per id).
        let mut ids: Vec<u64> = inflight.iter().map(|(t, ..)| t.request_id()).collect();
        ids.dedup();
        check_that!(ids.len() == nreq);
        let mut gold = LongDivider::new();
        for (ticket, fmt, rm, a, b) in inflight {
            let resp = ticket.wait().map_err(|e| e.to_string())?;
            check_that!(resp.fmt == fmt && resp.rm == rm, "typed echo");
            check_that!(resp.lanes() == a.len());
            for i in 0..a.len() {
                let want = gold.div_bits(a[i], b[i], fmt, rm);
                check_that!(
                    resp.bits[i] == want,
                    "{}/{:?} lane {i}: {:#x} vs {:#x}",
                    fmt.name(),
                    rm,
                    resp.bits[i],
                    want
                );
            }
        }
        Ok(())
    });
    svc.shutdown();
}
