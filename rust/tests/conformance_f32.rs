//! Sharded exhaustive-divisor binary32 conformance (the f32 face of
//! `conformance_f16.rs`, via [`tsdiv::verify::conformance`]).
//!
//! f32's divisor space is too large for one exhaustive cross, so the
//! 2^23-mantissa space is partitioned into deterministic slices keyed
//! by `(slice_index, slice_count)`: slice `s` owns every mantissa
//! ≡ `s (mod count)`. CI sweeps one rotating slice per pass (the run
//! number picks the slice, so successive runs walk the whole space);
//! the printed `TSDIV_F32_SLICE=… TSDIV_F32_SLICE_COUNT=…` pair replays
//! any pass locally, bit for bit. The `#[ignore]`d full test covers
//! every mantissa exactly once with the (exponent binade, rounding
//! mode) pair rotating with period 28.
//!
//! Each lane runs through the Taylor kernel *and* the Goldschmidt
//! kernel against the exactly-rounded gold reference: specials
//! bit-identical, finite lanes within ≤ 2 ulp, NaN lanes NaN on both
//! sides. A subsampled smoke slice keeps the harness honest inside the
//! regular suite.

use tsdiv::verify::conformance::{
    sweep_f32_full, sweep_f32_slice, DIVISOR_EXPONENTS, F32_MANTISSAS,
};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// The rotating CI slice: `TSDIV_F32_SLICE` (any integer — reduced mod
/// the count, so a CI run number works directly) selects the slice out
/// of `TSDIV_F32_SLICE_COUNT` (default 1024 ⇒ 8192 mantissas, ~3.9 M
/// lanes per backend per pass).
#[test]
#[ignore = "one full-cross f32 slice (~3.9M lanes/backend at the default count); run: \
            TSDIV_F32_SLICE=0 cargo test --release --test conformance_f32 -- --ignored ci_slice"]
fn conformance_f32_ci_slice() {
    let count = env_u64("TSDIV_F32_SLICE_COUNT", 1024).max(1);
    let raw = env_u64("TSDIV_F32_SLICE", 0);
    let slice = raw % count;
    println!(
        "f32 conformance slice {slice}/{count} (raw index {raw}); replay: \
         TSDIV_F32_SLICE={slice} TSDIV_F32_SLICE_COUNT={count} \
         cargo test --release --test conformance_f32 -- --ignored ci_slice --nocapture"
    );
    let r = sweep_f32_slice(slice, count);
    println!(
        "swept {} divisors / {} lanes per backend; max finite deviation: \
         kernel {} ulp, goldschmidt {} ulp",
        r.divisors, r.lanes_per_backend, r.max_ulp_kernel, r.max_ulp_goldschmidt
    );
    assert!(r.max_ulp_kernel <= 2 && r.max_ulp_goldschmidt <= 2);
}

/// Every f32 mantissa exactly once (exponent binade and rounding mode
/// rotating with period 28): ~143 M lanes per backend, about a minute
/// in release.
#[test]
#[ignore = "full 2^23-mantissa f32 sweep (~143M lanes/backend); run: \
            cargo test --release --test conformance_f32 -- --ignored"]
fn conformance_f32_full_rotation_vs_gold() {
    let r = sweep_f32_full();
    assert_eq!(r.divisors, F32_MANTISSAS, "each mantissa must be swept exactly once");
    println!(
        "f32 full rotation: {} divisors / {} lanes per backend; max finite deviation: \
         kernel {} ulp, goldschmidt {} ulp",
        r.divisors, r.lanes_per_backend, r.max_ulp_kernel, r.max_ulp_goldschmidt
    );
}

/// Subsampled smoke slice (64 mantissas) inside the regular suite, so
/// the sharding harness itself cannot bitrot.
#[test]
fn conformance_f32_slice_smoke() {
    let count = 1 << 17;
    let r = sweep_f32_slice(17, count);
    assert_eq!(r.divisors, (F32_MANTISSAS / count) * DIVISOR_EXPONENTS.len() as u64);
    assert!(r.lanes_per_backend > r.divisors);
    assert!(r.max_ulp_kernel <= 2 && r.max_ulp_goldschmidt <= 2);
}
