"""Python-side Table-I derivation consistency (mirrors Rust pla tests)."""

import numpy as np

from compile.kernels import ref

PAPER_TABLE_I = [1.09811, 1.20835, 1.3269, 1.45709, 1.59866, 1.75616, 1.92922, 2.12392]


def test_eight_segments_for_paper_config():
    bounds = ref.derive_segments(5, 53)
    assert len(bounds) == 9  # 1.0 + 8 boundaries


def test_first_boundary_matches_paper_tightly():
    bounds = ref.derive_segments(5, 53)
    assert abs(bounds[1] - PAPER_TABLE_I[0]) / PAPER_TABLE_I[0] < 5e-5


def test_all_boundaries_close_to_paper():
    bounds = ref.derive_segments(5, 53)
    for ours, paper in zip(bounds[1:], PAPER_TABLE_I):
        assert abs(ours - paper) / paper < 5e-3


def test_recurrence_is_geometric():
    bounds = ref.derive_segments(5, 53)
    r0 = bounds[1] / bounds[0]
    for a, b in zip(bounds[:-1], bounds[1:]):
        assert abs(b / a / r0 - 1) < 1e-9


def test_seed_tables_shapes_and_ranges():
    edges, slopes, intercepts = ref.segment_tables()
    assert edges.shape == slopes.shape == intercepts.shape == (8,)
    assert (slopes > 0).all() and (intercepts > 0).all()
    x = np.linspace(1.0, 1.999, 512, dtype=np.float32)
    y0 = np.asarray(ref.seed_ref(x))
    m = 1 - x * y0
    assert m.max() < 2.3e-3  # m_max for the Table-I partition
    assert m.min() > -1e-6
