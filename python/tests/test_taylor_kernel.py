"""Taylor-reciprocal Pallas kernel vs the jnp oracle and exact 1/x."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref, taylor_div


def run_recip(x, order=3, block=None):
    x = np.asarray(x, dtype=np.float32)
    return np.asarray(
        taylor_div.recip(x, order=order, block=block or len(x))
    )


def test_matches_jnp_oracle_elementwise():
    x = np.linspace(1.0, 1.9999999, 1024, dtype=np.float32)
    out = run_recip(x)
    want = np.asarray(ref.recip_ref(x, order=3))
    # The kernel uses the §6 max-squaring schedule; the oracle a
    # sequential Horner order — agreement to a couple of ulps, not bits.
    assert_allclose(out, want, rtol=3e-7, atol=0)


@pytest.mark.parametrize("order", [0, 1, 2, 3, 5])
def test_accuracy_improves_with_order(order):
    x = np.linspace(1.0, 1.9999999, 4096, dtype=np.float32)
    out = run_recip(x, order=order)
    err = np.abs(out.astype(np.float64) - 1.0 / x.astype(np.float64))
    # Bound from eq (17) with Table-I segments (m_max ≈ 2.2e-3), plus f32 noise.
    m_max = 2.2e-3
    bound = m_max ** (order + 1) / (1 - m_max) ** (order + 2) + 2e-7
    assert err.max() < bound, f"order {order}: {err.max():.3e} vs {bound:.3e}"


def test_order3_reaches_f32_roundoff():
    x = np.linspace(1.0, 1.9999999, 8192, dtype=np.float32)
    out = run_recip(x, order=3)
    want = (1.0 / x.astype(np.float64)).astype(np.float32)
    ulp = np.abs(out.view(np.int32) - want.view(np.int32))
    assert ulp.max() <= 4, f"max ulp {ulp.max()}"
    assert (ulp <= 1).mean() > 0.95


def test_segment_edges_continuous():
    # Seed is continuous-ish across Table-I edges; reciprocal must not jump.
    edges, _, _ = ref.segment_tables()
    pts = []
    for e in edges[:-1]:
        pts += [np.nextafter(e, 0, dtype=np.float32), e, np.nextafter(e, 2, dtype=np.float32)]
    # Pad to a clean batch.
    while len(pts) % 8:
        pts.append(np.float32(1.5))
    x = np.array(pts, dtype=np.float32)
    out = run_recip(x)
    want = 1.0 / x.astype(np.float64)
    assert_allclose(out, want, rtol=3e-7)


def test_tiling_invariance():
    rng = np.random.default_rng(5)
    x = (1.0 + rng.random(4096)).astype(np.float32)
    np.testing.assert_array_equal(
        run_recip(x, block=4096), run_recip(x, block=256)
    )


@settings(max_examples=40, deadline=None)
@given(
    xs=st.lists(
        st.floats(
            min_value=1.0,
            max_value=np.float32(1.9999999),
            allow_nan=False,
            width=32,
        ),
        min_size=64,
        max_size=64,
    ),
    order=st.integers(1, 5),
)
def test_hypothesis_error_within_eq17_bound(xs, order):
    x = np.array(xs, dtype=np.float32)
    out = run_recip(x, order=order)
    err = np.abs(out.astype(np.float64) - 1.0 / x.astype(np.float64))
    m_max = 2.2e-3
    bound = m_max ** (order + 1) / (1 - m_max) ** (order + 2) + 5e-7
    assert err.max() < bound
