"""Pallas ILM kernel vs the scalar oracle — bit-exact comparison."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ilm, ref

SMALL = 256  # batch used by the hypothesis sweeps (block=SMALL → 1 grid step)


def run_kernel(n1, n2, iterations):
    n1 = np.asarray(n1, dtype=np.int32)
    n2 = np.asarray(n2, dtype=np.int32)
    return np.asarray(ilm.ilm_mul(n1, n2, iterations=iterations, block=len(n1)))


def test_zero_operands_give_zero():
    n1 = np.array([0, 5, 0, 123], dtype=np.int32)
    n2 = np.array([7, 0, 0, 99], dtype=np.int32)
    out = run_kernel(n1, n2, 3)
    assert out.tolist() == [0, 0, 0, ref.ilm_mul_scalar(123, 99, 3)]


def test_powers_of_two_exact_at_zero_iterations():
    n1 = np.array([1, 2, 4, 1024, 16384], dtype=np.int32)
    n2 = np.array([8, 8, 8, 8, 2], dtype=np.int32)
    out = run_kernel(n1, n2, 0)
    assert out.tolist() == (n1.astype(np.int64) * n2).tolist()


def test_known_small_case():
    # 3·3: Mitchell gives 8; one correction recovers 9.
    out0 = run_kernel([3], [3], 0)
    out1 = run_kernel([3], [3], 1)
    assert out0[0] == 8 and out1[0] == 9


@pytest.mark.parametrize("iterations", [0, 1, 2, 3, 6])
def test_matches_oracle_randomized(iterations):
    rng = np.random.default_rng(42 + iterations)
    n1 = rng.integers(0, ref.ILM_MAX_OPERAND, size=1024, dtype=np.int32)
    n2 = rng.integers(0, ref.ILM_MAX_OPERAND, size=1024, dtype=np.int32)
    out = run_kernel(n1, n2, iterations)
    want = ref.ilm_mul_ref(n1, n2, iterations)
    np.testing.assert_array_equal(out.astype(np.int64), want)


def test_full_iterations_equal_exact_product():
    rng = np.random.default_rng(7)
    n1 = rng.integers(1, ref.ILM_MAX_OPERAND, size=2048, dtype=np.int32)
    n2 = rng.integers(1, ref.ILM_MAX_OPERAND, size=2048, dtype=np.int32)
    out = run_kernel(n1, n2, 14)  # 15-bit operands: 14 corrections suffice
    np.testing.assert_array_equal(
        out.astype(np.int64), n1.astype(np.int64) * n2.astype(np.int64)
    )


def test_grid_tiling_matches_single_block():
    rng = np.random.default_rng(11)
    n1 = rng.integers(0, ref.ILM_MAX_OPERAND, size=4096, dtype=np.int32)
    n2 = rng.integers(0, ref.ILM_MAX_OPERAND, size=4096, dtype=np.int32)
    one_block = np.asarray(ilm.ilm_mul(n1, n2, iterations=2, block=4096))
    tiled = np.asarray(ilm.ilm_mul(n1, n2, iterations=2, block=512))
    np.testing.assert_array_equal(one_block, tiled)


def test_error_monotone_in_iterations():
    rng = np.random.default_rng(3)
    n1 = rng.integers(1, ref.ILM_MAX_OPERAND, size=512, dtype=np.int32)
    n2 = rng.integers(1, ref.ILM_MAX_OPERAND, size=512, dtype=np.int32)
    exact = n1.astype(np.int64) * n2.astype(np.int64)
    prev_err = None
    for it in range(5):
        out = run_kernel(n1, n2, it).astype(np.int64)
        assert (out <= exact).all(), "ILM must never overshoot"
        err = (exact - out).sum()
        if prev_err is not None:
            assert err <= prev_err
        prev_err = err


@settings(max_examples=30, deadline=None)
@given(
    data=st.lists(
        st.tuples(
            st.integers(0, ref.ILM_MAX_OPERAND),
            st.integers(0, ref.ILM_MAX_OPERAND),
        ),
        min_size=SMALL,
        max_size=SMALL,
    ),
    iterations=st.integers(0, 6),
)
def test_hypothesis_kernel_equals_oracle(data, iterations):
    n1 = np.array([a for a, _ in data], dtype=np.int32)
    n2 = np.array([b for _, b in data], dtype=np.int32)
    out = run_kernel(n1, n2, iterations)
    want = ref.ilm_mul_ref(n1, n2, iterations)
    np.testing.assert_array_equal(out.astype(np.int64), want)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, ref.ILM_MAX_OPERAND), it=st.integers(0, 14))
def test_hypothesis_square_via_mul_matches_square_oracle(n, it):
    # The squaring unit is the ILM on equal operands (paper §5).
    out = run_kernel([n], [n], it)
    assert int(out[0]) == ref.ilm_square_scalar(n, it)
