"""L2 batched divide graph vs np.float32 division, specials included."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model


def run_divide(a, b, order=3):
    a = np.asarray(a, dtype=np.float32)
    b = np.asarray(b, dtype=np.float32)
    return np.asarray(model.divide_f32(a, b, order=order))


def ulp32(x, y):
    """ULP distance on the ordered-int mapping (NaNs excluded upstream)."""
    xi = x.view(np.int32).astype(np.int64)
    yi = y.view(np.int32).astype(np.int64)
    xi = np.where(xi < 0, np.int64(-(2**31)) - xi, xi)
    yi = np.where(yi < 0, np.int64(-(2**31)) - yi, yi)
    return np.abs(xi - yi)


def test_simple_quotients():
    a = np.array([6.0, 1.0, -7.5, 84.0], dtype=np.float32)
    b = np.array([2.0, 2.0, 2.5, 2.0], dtype=np.float32)
    np.testing.assert_array_equal(run_divide(a, b), a / b)


def test_specials_table():
    inf, nan = np.float32(np.inf), np.float32(np.nan)
    cases = [
        (nan, 1.0), (1.0, nan), (inf, inf), (-inf, inf),
        (0.0, 0.0), (-0.0, 0.0), (1.0, 0.0), (-1.0, 0.0),
        (1.0, -0.0), (0.0, 5.0), (-0.0, 5.0), (inf, -2.0),
        (3.0, inf), (-3.0, inf), (inf, 0.0), (0.0, inf),
    ]
    a = np.array([c[0] for c in cases], dtype=np.float32)
    b = np.array([c[1] for c in cases], dtype=np.float32)
    out = run_divide(a, b)
    want = a / b
    nan_mask = np.isnan(want)
    assert (np.isnan(out) == nan_mask).all()
    # Non-NaN lanes must match exactly (inf/zero with correct sign).
    np.testing.assert_array_equal(out[~nan_mask], want[~nan_mask])


def test_normal_randoms_within_1_ulp():
    rng = np.random.default_rng(0)
    a = (rng.random(8192, dtype=np.float32) + 0.1) * 10.0 ** rng.integers(-10, 10, 8192)
    b = (rng.random(8192, dtype=np.float32) + 0.1) * 10.0 ** rng.integers(-10, 10, 8192)
    a = a.astype(np.float32)
    b = b.astype(np.float32)
    out = run_divide(a, b)
    want = a / b
    finite = np.isfinite(want) & (want != 0)
    assert ulp32(out[finite], want[finite]).max() <= 1


def test_exact_rate_high():
    rng = np.random.default_rng(1)
    a = (1.0 + rng.random(16384)).astype(np.float32)
    b = (1.0 + rng.random(16384)).astype(np.float32)
    out = run_divide(a, b)
    want = a / b
    exact = (out.view(np.int32) == want.view(np.int32)).mean()
    # f32-arithmetic datapath + one residual-correction step: ~86 %
    # bit-exact, never more than 1 ulp off (the Rust 60-bit datapath is
    # the bit-exact hardware model; this is the vectorized f32 variant).
    assert exact > 0.8, f"exact rate {exact}"


def test_sign_symmetry():
    rng = np.random.default_rng(2)
    a = (1.0 + rng.random(256)).astype(np.float32)
    b = (1.0 + rng.random(256)).astype(np.float32)
    qpp = run_divide(a, b)
    qnp = run_divide(-a, b)
    qpn = run_divide(a, -b)
    qnn = run_divide(-a, -b)
    np.testing.assert_array_equal(qpp, -qnp)
    np.testing.assert_array_equal(qpp, -qpn)
    np.testing.assert_array_equal(qpp, qnn)


def test_power_of_two_divisors_exact():
    rng = np.random.default_rng(3)
    a = (1.0 + rng.random(512)).astype(np.float32)
    for k in [-8, -1, 0, 1, 7]:
        b = np.full(512, 2.0**k, dtype=np.float32)
        np.testing.assert_array_equal(run_divide(a, b), a / b)


def test_reciprocal_entry():
    b = np.linspace(0.5, 8.0, 1024, dtype=np.float32)
    out = np.asarray(model.reciprocal_f32(b))
    want = np.float32(1.0) / b
    finite = np.isfinite(want)
    # reciprocal = 1·recip(mantissa) route: one extra rounding vs `/`.
    assert ulp32(out[finite], want[finite]).max() <= 2


def test_make_divide_returns_tuple_entry():
    fn, specs = model.make_divide(256)
    a = np.full(256, 10.0, dtype=np.float32)
    b = np.full(256, 4.0, dtype=np.float32)
    out = fn(a, b)
    assert isinstance(out, tuple) and len(out) == 1
    np.testing.assert_array_equal(np.asarray(out[0]), a / b)


def _normal_or_zero():
    """f32 values that are 0 or normal-range: XLA CPU/TPU are DAZ/FTZ,
    so subnormal operands are architecturally equal to zero there (the
    Rust datapath, not this graph, models gradual underflow)."""
    nonzero = st.floats(
        min_value=np.float32(1.2e-38),
        max_value=np.float32(1e30),
        allow_nan=False,
        width=32,
    ).map(np.float32)
    return st.one_of(
        st.just(np.float32(0.0)),
        nonzero,
        nonzero.map(lambda v: np.float32(-v)),
    )


@settings(max_examples=30, deadline=None)
@given(
    ab=st.lists(
        st.tuples(_normal_or_zero(), _normal_or_zero()),
        min_size=32,
        max_size=32,
    )
)
def test_hypothesis_matches_numpy_division(ab):
    a = np.array([x for x, _ in ab], dtype=np.float32)
    b = np.array([y for _, y in ab], dtype=np.float32)
    out = run_divide(a, b)
    want = a / b
    nan_mask = np.isnan(want)
    assert (np.isnan(out) == nan_mask).all()
    ok = ~nan_mask & np.isfinite(want) & (np.abs(want) >= 1e-37)
    if ok.any():
        assert ulp32(out[ok], want[ok]).max() <= 1
    # Infinite / zero reference lanes: sign and class must agree.
    special = ~nan_mask & ~ok
    if special.any():
        np.testing.assert_array_equal(
            np.signbit(out[special]), np.signbit(want[special])
        )
        inf_lane = np.isinf(want[special])
        assert (np.isinf(out[special]) == inf_lane).all()


@pytest.mark.parametrize("batch", [256, 1024])
def test_aot_lowering_produces_hlo_text(batch, tmp_path):
    import jax
    from compile import aot

    fn, specs = model.make_divide(batch)
    text = aot.lower_entry(fn, specs)
    assert "HloModule" in text
    assert f"f32[{batch}]" in text
