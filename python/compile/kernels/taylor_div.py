"""Pallas kernel: batched Taylor-series mantissa reciprocal (paper §2-3, 6).

The f32 datapath of the paper's Fig-7 system as a vector kernel:

1. PLA seed (eq 15, Table-I segments): the 8-way segment select is a sum
   of compare masks — the vector analogue of the hardware compare tree;
2. ``m = 1 − x·y0`` (eq 16);
3. powers of ``m`` per the §6 "maximize squaring" schedule — even powers
   as squares of lower powers, odd powers as ``even · m`` — statically
   unrolled;
4. accumulate and the final ``y0 · S`` multiply (eq 11).

Order 3 already exceeds f32 precision (m ≤ 2.2e-3 ⇒ m⁴ ≈ 2e-11 ≪ 2^-24);
the order stays configurable for the accuracy-sweep benches.

Lowered with ``interpret=True`` — CPU PJRT cannot run Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK = 2048


def _seed(x, edges, slopes, intercepts):
    """Vectorized PLA seed: mask-sum per segment (compare tree analogue)."""
    y0 = jnp.zeros_like(x)
    n = len(edges)
    lo = 1.0
    for i in range(n):
        hi = edges[i]
        # Segment i covers [lo, hi); the last one also catches x ≥ last edge.
        in_seg = (x >= lo) & (x < hi) if i + 1 < n else (x >= lo)
        y0 = y0 + jnp.where(in_seg, intercepts[i] - slopes[i] * x, 0.0)
        lo = hi
    return y0


def _powers_max_squaring(m, order):
    """m¹..m^order per the §6 schedule: evens are squares, odds are
    even·m with the cached base operand."""
    powers = {1: m}
    for p in range(2, order + 1):
        if p % 2 == 0:
            half = powers[p // 2]
            powers[p] = half * half  # squaring unit
        else:
            powers[p] = powers[p - 1] * m  # multiplier with cached m
    return [powers[p] for p in range(1, order + 1)]


def recip_kernel_body(x_ref, out_ref, *, order, edges, slopes, intercepts):
    x = x_ref[...]
    y0 = _seed(x, edges, slopes, intercepts)
    m = 1.0 - x * y0
    s = jnp.ones_like(m)
    if order >= 1:
        for mk in _powers_max_squaring(m, order):
            s = s + mk
    out_ref[...] = y0 * s


@functools.partial(jax.jit, static_argnames=("order", "block"))
def recip(x, order: int = 3, block: int = DEFAULT_BLOCK):
    """Batched Taylor reciprocal of f32 mantissas in [1, 2)."""
    n = x.shape[0]
    assert x.ndim == 1
    blk = min(block, n)
    assert n % blk == 0, f"batch {n} not a multiple of block {blk}"
    edges, slopes, intercepts = ref.segment_tables()
    kernel = functools.partial(
        recip_kernel_body,
        order=order,
        edges=tuple(float(v) for v in edges),
        slopes=tuple(float(v) for v in slopes),
        intercepts=tuple(float(v) for v in intercepts),
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=(n // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        interpret=True,
    )(x.astype(jnp.float32))
