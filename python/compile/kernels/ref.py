"""Pure-jnp / numpy correctness oracles for the Pallas kernels.

Each kernel in this package has a reference here written in the most
obviously-correct style available (scalar numpy loops for the bit-exact
ILM; plain jnp float ops for the Taylor datapath), so pytest can assert
kernel == oracle without the two sharing code.
"""

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Iterative Logarithmic Multiplier (paper §4, eq 21-27)
# ---------------------------------------------------------------------------

#: Operand limit for the int32 ILM kernel: products of two 15-bit values
#: stay below 2^30, comfortably inside int32.
ILM_MAX_OPERAND = (1 << 15) - 1


def ilm_mul_scalar(n1: int, n2: int, iterations: int) -> int:
    """Bit-exact scalar ILM (Python ints — cannot overflow)."""
    if n1 == 0 or n2 == 0:
        return 0

    def basic(a, b):
        k1, k2 = a.bit_length() - 1, b.bit_length() - 1
        r1, r2 = a ^ (1 << k1), b ^ (1 << k2)
        p0 = (1 << (k1 + k2)) + (r1 << k2) + (r2 << k1)
        return p0, r1, r2

    acc, r1, r2 = basic(n1, n2)
    for _ in range(iterations):
        if r1 == 0 or r2 == 0:
            break
        p, r1, r2 = basic(r1, r2)
        acc += p
    return acc


def ilm_mul_ref(n1, n2, iterations: int):
    """Vectorized reference over numpy arrays (element-wise scalar calls)."""
    n1 = np.asarray(n1)
    n2 = np.asarray(n2)
    out = np.empty(n1.shape, dtype=np.int64)
    for idx in np.ndindex(n1.shape):
        out[idx] = ilm_mul_scalar(int(n1[idx]), int(n2[idx]), iterations)
    return out


def ilm_square_scalar(n: int, iterations: int) -> int:
    """Bit-exact scalar squaring unit (paper §5, eq 28)."""
    if n == 0:
        return 0

    def basic(a):
        k = a.bit_length() - 1
        r = a ^ (1 << k)
        return (1 << (2 * k)) + (r << (k + 1)), r

    acc, r = basic(n)
    for _ in range(iterations):
        if r == 0:
            break
        p, r = basic(r)
        acc += p
    return acc


# ---------------------------------------------------------------------------
# Piecewise-linear seed + Taylor reciprocal (paper §2-3)
# ---------------------------------------------------------------------------

def derive_segments(n: int, pr_max: int) -> list:
    """Paper §3 boundary recurrence (eq 19/20), solved by bisection.

    Mirrors the Rust ``pla::derive_segments``; the Table-I configuration
    is ``derive_segments(5, 53)``.
    """

    def bound_log2(a, b):
        mm = ((b - a) / (a + b)) ** 2
        xi = (a + b) ** 2 / (4 * a * b)
        return (n + 2) * np.log2(xi) + (n + 1) * np.log2(mm)

    bounds = [1.0]
    a = 1.0
    while bounds[-1] < 2.0:
        lo, hi = a * (1 + 1e-15), a * 2.0
        while bound_log2(a, hi) < -pr_max:
            hi *= 2.0
        for _ in range(200):
            mid = 0.5 * (lo + hi)
            if bound_log2(a, mid) <= -pr_max:
                lo = mid
            else:
                hi = mid
        bounds.append(lo)
        a = lo
    return bounds


def segment_tables(order: int = 5, pr_max: int = 53):
    """(edges, slopes, intercepts) f32 arrays for the seed datapath."""
    bounds = derive_segments(order, pr_max)
    edges = np.array(bounds[1:], dtype=np.float32)
    slopes = np.array(
        [4.0 / (a + b) ** 2 for a, b in zip(bounds[:-1], bounds[1:])],
        dtype=np.float32,
    )
    intercepts = np.array(
        [4.0 / (a + b) for a, b in zip(bounds[:-1], bounds[1:])],
        dtype=np.float32,
    )
    return edges, slopes, intercepts


def seed_ref(x, order: int = 5, pr_max: int = 53):
    """PLA seed y0(x) for x in [1,2), plain jnp (eq 15 per segment)."""
    edges, slopes, intercepts = segment_tables(order, pr_max)
    x = jnp.asarray(x, dtype=jnp.float32)
    idx = jnp.sum(
        x[..., None] >= jnp.asarray(edges)[None, :], axis=-1
    ).astype(jnp.int32)
    idx = jnp.clip(idx, 0, len(edges) - 1)
    s = jnp.asarray(slopes)[idx]
    c = jnp.asarray(intercepts)[idx]
    return c - s * x


def recip_ref(x, order: int = 3):
    """Taylor reciprocal of x in [1,2): y0 · (1 + m + … + m^order)."""
    x = jnp.asarray(x, dtype=jnp.float32)
    y0 = seed_ref(x)
    m = 1.0 - x * y0
    s = jnp.ones_like(m)
    mk = jnp.ones_like(m)
    for _ in range(order):
        mk = mk * m
        s = s + mk
    return y0 * s


def divide_ref(a, b):
    """Reference division: plain jnp `/` (XLA's correctly-rounded f32 path)."""
    return jnp.asarray(a, jnp.float32) / jnp.asarray(b, jnp.float32)
