"""Pallas kernel: batched Iterative Logarithmic Multiplier (paper §4).

TPU adaptation of the ILM (see DESIGN.md §Hardware-Adaptation): the
priority encoder becomes a vectorized ``floor(log2)`` over int32 lanes,
the bit-clear an XOR with the isolated leading one, and the correction
recursion a statically unrolled loop over the whole VMEM-resident block.
Operands are limited to 15 bits so every intermediate fits int32.

Lowered with ``interpret=True`` — mandatory on the CPU PJRT backend.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Default lane-block processed per grid step. 2048 int32 lanes = 8 KiB
#: per operand block in VMEM — three blocks (two in, one out) stay far
#: under the ~16 MiB VMEM budget; see EXPERIMENTS.md §Perf L1.
DEFAULT_BLOCK = 2048


def _leading_one(v):
    """(k, 2^k) for each lane of v (v > 0). Smear-and-isolate bit trick:
    OR-propagate the MSB downward; the smeared value is 2^(k+1) − 1, so
    the LOD is (smeared+1)>>1 and the priority-encoder output is
    popcount(smeared) − 1 (exact integer arithmetic; XLA's f32 log2 is
    NOT exact on powers of two).
    """
    v = v.astype(jnp.int32)
    s = v
    s = s | (s >> 1)
    s = s | (s >> 2)
    s = s | (s >> 4)
    s = s | (s >> 8)
    # 15-bit operands: 8 bits of smear are enough (1+2+4+8 covers 15).
    lod = (s + 1) >> 1  # isolated leading one (power of two)
    k = jax.lax.population_count(s) - 1
    return k, lod


def _basic_block(n1, n2):
    """One P_approx evaluation (eq 24) + residues (eq 25)."""
    k1, lod1 = _leading_one(n1)
    k2, lod2 = _leading_one(n2)
    r1 = n1 ^ lod1
    r2 = n2 ^ lod2
    p0 = (
        jnp.left_shift(jnp.int32(1), k1 + k2)
        + jnp.left_shift(r1, k2)
        + jnp.left_shift(r2, k1)
    )
    return p0, r1, r2


def ilm_kernel_body(n1_ref, n2_ref, out_ref, *, iterations: int):
    """Kernel body: ILM product of one block with `iterations` corrections."""
    n1 = n1_ref[...]
    n2 = n2_ref[...]
    live = (n1 > 0) & (n2 > 0)
    # Zero operands would break the priority encoder; substitute 1 and
    # mask the result dead at the end.
    n1s = jnp.where(live, n1, 1)
    n2s = jnp.where(live, n2, 1)
    acc, r1, r2 = _basic_block(n1s, n2s)
    for _ in range(iterations):
        stage_live = (r1 > 0) & (r2 > 0)
        p, nr1, nr2 = _basic_block(
            jnp.where(stage_live, r1, 1), jnp.where(stage_live, r2, 1)
        )
        acc = acc + jnp.where(stage_live, p, 0)
        r1 = jnp.where(stage_live, nr1, 0)
        r2 = jnp.where(stage_live, nr2, 0)
    out_ref[...] = jnp.where(live, acc, 0)


@functools.partial(jax.jit, static_argnames=("iterations", "block"))
def ilm_mul(n1, n2, iterations: int = 3, block: int = DEFAULT_BLOCK):
    """Batched ILM product of int32 operands in [0, 2^15).

    ``iterations`` correction stages are unrolled statically (the paper's
    fixed-hardware-budget mode). The batch is tiled into VMEM blocks of
    ``block`` lanes by the Pallas grid.
    """
    n = n1.shape[0]
    assert n1.shape == n2.shape and n1.ndim == 1
    blk = min(block, n)
    assert n % blk == 0, f"batch {n} not a multiple of block {blk}"
    kernel = functools.partial(ilm_kernel_body, iterations=iterations)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(n1.astype(jnp.int32), n2.astype(jnp.int32))
