"""L2: the batched f32 division graph (paper Fig 7 at batch scale).

``divide_f32`` wraps the L1 Taylor-reciprocal Pallas kernel with the
IEEE-754 machinery the hardware's special/exponent path performs:
mantissa/exponent split (frexp), the mantissa reciprocal, exponent
recombination (ldexp), and special-value selection (NaN/Inf/zero rules).

This module is lowered ONCE by ``aot.py`` into ``artifacts/*.hlo.txt``
and executed from the Rust coordinator via PJRT — Python never serves a
request.
"""

import jax
import jax.numpy as jnp

from .kernels import taylor_div


def mantissa_reciprocal(b_abs, order: int = 3):
    """1/|b| for positive finite b: frexp → kernel reciprocal → ldexp.

    |b| = mb·2^eb with mb ∈ [0.5, 1); x = 2·mb ∈ [1, 2);
    1/|b| = (1/x)·2^(1−eb).
    """
    mb, eb = jnp.frexp(b_abs)
    x = 2.0 * mb
    r = taylor_div.recip(x, order=order)
    return jnp.ldexp(r, 1 - eb)


def divide_f32(a, b, order: int = 3):
    """Batched IEEE-ish f32 division via the Taylor/PLA datapath.

    Accuracy: ≤ 1 ulp vs `/` on normal results (order-3 reciprocal error
    ≈ 2e-11, far below f32's 2^-24 half-ulp, plus one residual-correction
    step).

    Subnormals: XLA's CPU (and TPU) backends run DAZ/FTZ — subnormal
    operands compare equal to zero and subnormal results flush. This
    graph therefore has accelerator subnormal semantics; the bit-exact
    gradual-underflow datapath lives in the Rust `fp`/`divider` modules.
    """
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    sign = jnp.bitwise_xor(jnp.signbit(a), jnp.signbit(b))
    signed = lambda mag: jnp.where(sign, -mag, mag)

    b_abs = jnp.abs(b)
    a_abs = jnp.abs(a)
    # Substitute a safe divisor on the special lanes; mask afterwards.
    b_safe = jnp.where((b_abs > 0) & jnp.isfinite(b_abs), b_abs, 1.0)
    r = mantissa_reciprocal(b_safe, order=order)
    q = a_abs * r
    # One residual-correction step (the hardware's rounding stage works
    # from the unrounded product; in f32 arithmetic we recover the lost
    # bits with the standard refinement q += r·(a − q·b)). Guarded: when
    # q or r overflowed (true quotient ±inf) the residual is inf−inf.
    q_ref = q + r * (a_abs - q * b_safe)
    q = jnp.where(jnp.isfinite(q_ref), q_ref, q)

    nan = (
        jnp.isnan(a)
        | jnp.isnan(b)
        | ((a_abs == 0) & (b_abs == 0))
        | (jnp.isinf(a_abs) & jnp.isinf(b_abs))
    )
    inf = (jnp.isinf(a_abs) | (b_abs == 0)) & ~nan
    zero = ((a_abs == 0) | jnp.isinf(b_abs)) & ~nan

    out = q
    out = jnp.where(zero, 0.0, out)
    out = jnp.where(inf, jnp.inf, out)
    out = signed(out)
    out = jnp.where(nan, jnp.nan, out)
    return out


def reciprocal_f32(b, order: int = 3):
    """Batched reciprocal (the Fig-7 datapath without the final multiply)."""
    return divide_f32(jnp.ones_like(jnp.asarray(b, jnp.float32)), b, order=order)


def make_divide(batch: int, order: int = 3):
    """A jit-able entry of fixed batch shape, returning a 1-tuple (the
    AOT bridge lowers with return_tuple=True; see /opt/xla-example)."""

    def fn(a, b):
        return (divide_f32(a, b, order=order),)

    spec = jax.ShapeDtypeStruct((batch,), jnp.float32)
    return fn, (spec, spec)


def make_recip(batch: int, order: int = 3):
    def fn(b):
        return (reciprocal_f32(b, order=order),)

    spec = jax.ShapeDtypeStruct((batch,), jnp.float32)
    return fn, (spec,)


def make_ilm(batch: int, iterations: int = 3):
    from .kernels import ilm

    def fn(n1, n2):
        return (ilm.ilm_mul(n1, n2, iterations=iterations),)

    spec = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return fn, (spec, spec)
