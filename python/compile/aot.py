"""AOT bridge: lower the L2 graphs to HLO **text** for the Rust runtime.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids, which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage (from ``make artifacts``)::

    cd python && python -m compile.aot --out ../artifacts

Emits one ``.hlo.txt`` per (entry, batch) plus ``manifest.json``
describing shapes for the Rust loader, and ``model.hlo.txt`` as the
canonical divide artifact the Makefile tracks.
"""

import argparse
import json
import os
import shutil

import jax
from jax._src.lib import xla_client as xc

from . import model

#: Batch sizes built by default: the coordinator pads every request
#: batch up to the nearest entry.
BATCHES = (256, 1024, 4096)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def build_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "entries": []}

    def emit(name, fn, specs, meta):
        text = lower_entry(fn, specs)
        path = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        entry = {
            "name": name,
            "path": path,
            "inputs": [
                {"shape": list(s.shape), "dtype": s.dtype.name} for s in specs
            ],
            **meta,
        }
        manifest["entries"].append(entry)
        print(f"  wrote {path} ({len(text)} chars)")

    for batch in BATCHES:
        fn, specs = model.make_divide(batch)
        emit(f"divide_b{batch}", fn, specs, {"kind": "divide", "batch": batch})
    fn, specs = model.make_recip(1024)
    emit("recip_b1024", fn, specs, {"kind": "recip", "batch": 1024})
    fn, specs = model.make_ilm(1024)
    emit("ilm_b1024", fn, specs, {"kind": "ilm", "batch": 1024})

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # Canonical artifact tracked by the Makefile.
    shutil.copyfile(
        os.path.join(out_dir, "divide_b1024.hlo.txt"),
        os.path.join(out_dir, "model.hlo.txt"),
    )
    print(f"  wrote manifest.json ({len(manifest['entries'])} entries)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    out = args.out
    # `--out ../artifacts/model.hlo.txt` (old Makefile form) → directory.
    if out.endswith(".hlo.txt"):
        out = os.path.dirname(out)
    print(f"AOT-lowering to {out}/")
    build_all(out)


if __name__ == "__main__":
    main()
